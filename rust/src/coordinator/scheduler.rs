//! Continuous-batching scheduler.
//!
//! Every engine iteration the scheduler admits newly-arrived requests
//! (oldest first, while a batch slot and KV pages are free) and returns
//! the whole runnable set — unprefilled sequences run their prompt,
//! prefilled ones take one decode step.  `max_batch = 1` degenerates to
//! the paper's latency-oriented batch-size-1 regime (§1); larger values
//! give the Fig. 15 multi-batch mode.
//!
//! With `prefix_cache` on, admission consults the pool's prefix index:
//! a prompt whose full-page prefix is already materialized shares those
//! pages and is charged only its uncached suffix against free pages.
//! `SeqState::cached_ctx` records how many prompt tokens the backend may
//! skip at prefill.
//!
//! Chunked prefill + decode priority: `plan` converts the runnable set
//! into per-iteration work items.  Decodes always run; prefill work is
//! capped at `prefill_chunk` prompt tokens per iteration (0 = whole
//! prompt at once), handed out in admission order.  A long prompt is
//! thus spread over several iterations — `SeqState::prefill_pos` tracks
//! how far it has run — so in-flight decodes never stall behind one
//! monolithic prefill.  Chunking composes with prefix caching: the
//! first chunk starts at `cached_ctx` (cached pages are never re-run).
//!
//! Preemption & swap (§4.4 hybrid HBM/DDR placement): with
//! `SchedulerConfig::swap` on, KV exhaustion during decode no longer
//! truncates a sequence.  The NEWEST running sequence (latest
//! `admitted_s`, so the oldest requests keep their latency) is swapped
//! out to the DDR tier — pages freed, token image preserved — and
//! parked on the `preempted` queue; `schedule` swaps parked sequences
//! back in (oldest first, strict order) AHEAD of fresh admissions once
//! pages free up, and the sequence resumes exactly where it stopped.
//! Terminal `EvictedKvFull` survives only for a sequence that alone
//! exceeds the entire pool (it can never continue, swap or no swap).
//!
//! Accounting invariant (checked by `check_accounting` and the property
//! tests below): for every running sequence, `SeqState.ctx` equals the
//! KV pool's token count — the scheduler never believes in KV the pool
//! does not hold, cached or not — and every preempted sequence's `ctx`
//! equals its token count in the pool's swap registry.

use std::collections::VecDeque;

use crate::obs::{Event, Recorder};
use crate::workload::Request;

use super::kv_cache::PagePool;

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Concurrent sequences in flight (batch size; paper default 1).
    pub max_batch: usize,
    /// KV page pool geometry.
    pub kv_pages: usize,
    pub page_tokens: usize,
    /// Hard cap on context (model max_seq).
    pub max_seq: usize,
    /// Share full-page prompt prefixes across sequences (CoW paged KV).
    pub prefix_cache: bool,
    /// Per-iteration prefill token budget: a prompt longer than this is
    /// split into budget-sized chunks run over successive iterations,
    /// so decodes are never stalled behind one monolithic prefill.
    /// 0 = unchunked (the whole uncached prompt in one iteration).
    pub prefill_chunk: usize,
    /// Preempt + swap-to-DDR instead of terminally evicting on KV
    /// exhaustion: the newest resident is swapped out and later resumed,
    /// so overload degrades into priced DDR traffic, not truncation.
    pub swap: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_batch: 1,
            kv_pages: 64,
            page_tokens: 16,
            max_seq: 256,
            prefix_cache: false,
            prefill_chunk: 0,
            swap: false,
        }
    }
}

/// A running sequence.
#[derive(Debug)]
pub struct SeqState {
    pub req: Request,
    /// Tokens generated so far.
    pub generated: Vec<u32>,
    /// Context length currently in the KV cache (== pool tokens).
    pub ctx: usize,
    /// Prompt tokens served from the prefix cache at admission: the
    /// backend only prefills the remaining suffix.
    pub cached_ctx: usize,
    /// Prompt tokens already run through the backend (starts at
    /// `cached_ctx`; advances chunk by chunk under chunked prefill).
    pub prefill_pos: usize,
    /// Whether prefill has run to completion (first token produced).
    pub prefilled: bool,
    /// Virtual time the request was admitted.
    pub admitted_s: f64,
}

impl SeqState {
    pub fn done(&self) -> bool {
        self.prefilled && self.generated.len() >= self.req.max_new_tokens as usize
    }

    /// The KV cache holds `max_seq` tokens: no further decode possible.
    pub fn context_capped(&self, max_seq: usize) -> bool {
        self.ctx >= max_seq
    }

    /// Still has work to run this iteration.
    pub fn runnable(&self, max_seq: usize) -> bool {
        !self.prefilled || (!self.done() && !self.context_capped(max_seq))
    }
}

/// One sequence's work assignment for the coming engine iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanWork {
    /// Run prompt tokens `[start, end)` through the backend.  The chunk
    /// is final (produces the first token) iff `end` is the prompt
    /// length.
    Prefill { start: usize, end: usize },
    /// One decode step.
    Decode,
}

/// A planned slot: which sequence, and what it runs this iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanItem {
    pub seq: u64,
    pub work: PlanWork,
}

/// What one decode step did to a sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeOutcome {
    /// Still generating.
    Running,
    /// Reached its token budget or the context cap.
    Finished,
    /// The KV pool could not grow: the sequence must be retired now.
    /// `ctx` was NOT advanced, so scheduler context and pool tokens stay
    /// in sync (the produced token is still recorded).  With swap
    /// enabled this survives only for a sequence that alone exceeds the
    /// ENTIRE pool.
    EvictedKvFull,
    /// Swap mode: the sequence was the newest resident and preempted
    /// ITSELF to the DDR tier.  The produced token was dropped — `ctx`
    /// did not advance, so the resumed decode re-produces it at the same
    /// position (deterministic backends yield the identical token).  Not
    /// terminal: the engine must keep the request's streaming state.
    Preempted,
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    waiting: VecDeque<Request>,
    running: Vec<SeqState>,
    /// Sequences swapped out to DDR, awaiting resume (token images
    /// preserved in `SeqState`; page footprints in the pool's swap
    /// registry).
    preempted: Vec<SeqState>,
    /// Preempted sequences whose next decode step cannot fit even an
    /// empty pool: the engine drains these for terminal eviction.
    unresumable: Vec<SeqState>,
    pub pool: PagePool,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let pool = if cfg.prefix_cache {
            PagePool::with_prefix_cache(cfg.kv_pages, cfg.page_tokens)
        } else {
            PagePool::new(cfg.kv_pages, cfg.page_tokens)
        };
        Self {
            cfg,
            waiting: VecDeque::new(),
            running: Vec::new(),
            preempted: Vec::new(),
            unresumable: Vec::new(),
            pool,
        }
    }

    /// Queue a request.  Prompts longer than `max_seq` are truncated HERE
    /// so admission accounting, the backend's prefill, and the KV pool
    /// all see the same length (an oversized prompt can otherwise never
    /// be served — its KV would not fit the model's cache).
    pub fn submit(&mut self, mut req: Request) {
        req.prompt.truncate(self.cfg.max_seq);
        self.waiting.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    pub fn running(&self) -> &[SeqState] {
        &self.running
    }

    pub fn seq(&self, seq: u64) -> Option<&SeqState> {
        self.running.iter().find(|s| s.req.id == seq)
    }

    pub fn seq_mut(&mut self, seq: u64) -> Option<&mut SeqState> {
        self.running.iter_mut().find(|s| s.req.id == seq)
    }

    /// Arrival time of the oldest waiting request (the serving loop
    /// fast-forwards its virtual clock to this when idle).
    pub fn next_arrival_s(&self) -> Option<f64> {
        self.waiting.front().map(|r| r.arrival_s)
    }

    /// Index of the next preempted sequence to resume: strict oldest
    /// first (earliest `admitted_s`, ties by request id), so a resumed
    /// request is never leapfrogged by newer parked work.
    fn oldest_preempted(&self) -> Option<usize> {
        self.preempted
            .iter()
            .enumerate()
            .min_by(|a, b| {
                a.1.admitted_s
                    .total_cmp(&b.1.admitted_s)
                    .then(a.1.req.id.cmp(&b.1.req.id))
            })
            .map(|(i, _)| i)
    }

    /// Swap parked sequences back into free batch slots, oldest first.
    /// A resume is gated on room for the sequence AND its next decode
    /// token, so a freshly resumed sequence never preempts on its first
    /// step just to grow by one page.
    fn resume_preempted(&mut self) {
        while self.running.len() < self.cfg.max_batch {
            let Some(i) = self.oldest_preempted() else { break };
            let need = self.pool.pages_for(self.preempted[i].ctx + 1);
            if need > self.pool.total_pages() {
                // Can never fit even an empty pool: hand to the engine
                // for terminal eviction instead of spinning forever.
                let s = self.preempted.swap_remove(i);
                self.pool
                    .drop_swapped(s.req.id)
                    .expect("preempted sequence is parked in the swap tier");
                self.unresumable.push(s);
                continue;
            }
            if need > self.pool.free_pages() {
                break; // strict oldest-first: wait for pages, don't leapfrog
            }
            let s = self.preempted.swap_remove(i);
            self.pool.swap_in(s.req.id).expect("capacity checked above");
            self.running.push(s);
        }
    }

    /// Admit arrived requests while capacity allows, then return the ids
    /// runnable this iteration (admission order; unprefilled sequences
    /// run prefill, the rest one decode step each).  Swap-ins of
    /// preempted sequences take strict priority over fresh admissions —
    /// they already absorbed queueing latency once.  Admission charges
    /// only the uncached prompt suffix: a cached full-page prefix is
    /// shared, not reallocated.
    pub fn schedule(&mut self, now_s: f64) -> Vec<u64> {
        self.schedule_recorded(now_s, None)
    }

    /// [`Scheduler::schedule`] with a flight recorder: each admission
    /// emits `Admitted{cached_tokens}` stamped at `now_s`.  Recording
    /// reads scheduling state but never influences it.
    pub fn schedule_recorded(&mut self, now_s: f64, rec: Option<&Recorder>) -> Vec<u64> {
        self.resume_preempted();
        // While anything is still parked in the swap tier, fresh
        // admissions are frozen: a new prompt must not consume the
        // pages the oldest preempted sequence is waiting for (running
        // work keeps draining, so the freeze always lifts).
        while self.preempted.is_empty() && self.running.len() < self.cfg.max_batch {
            let Some(req) = self.waiting.front() else { break };
            if req.arrival_s > now_s || !self.pool.can_admit(&req.prompt) {
                break;
            }
            let req = self.waiting.pop_front().unwrap();
            let plen = req.prompt.len();
            let outcome = self
                .pool
                .admit(req.id, &req.prompt)
                .expect("can_admit guaranteed admission");
            if let Some(r) = rec {
                r.record(
                    now_s,
                    Event::Admitted { id: req.id, cached_tokens: outcome.cached_tokens as u32 },
                );
            }
            self.running.push(SeqState {
                req,
                generated: Vec::new(),
                ctx: plen,
                cached_ctx: outcome.cached_tokens,
                prefill_pos: outcome.cached_tokens,
                prefilled: false,
                admitted_s: now_s,
            });
        }
        self.running
            .iter()
            .filter(|s| s.runnable(self.cfg.max_seq))
            .map(|s| s.req.id)
            .collect()
    }

    /// Plan one engine iteration: admit arrivals, then convert the
    /// runnable set into work items with decode priority.  Every
    /// prefilled sequence decodes; prefilling sequences share a
    /// `prefill_chunk`-token budget (admission order, 0 = unlimited), so
    /// a long prompt runs as several chunks across iterations instead of
    /// freezing the batch for one monolithic prefill.
    pub fn plan(&mut self, now_s: f64) -> Vec<PlanItem> {
        self.plan_recorded(now_s, None)
    }

    /// [`Scheduler::plan`] with a flight recorder threaded through
    /// admission (see [`Scheduler::schedule_recorded`]).
    pub fn plan_recorded(&mut self, now_s: f64, rec: Option<&Recorder>) -> Vec<PlanItem> {
        let ids = self.schedule_recorded(now_s, rec);
        let mut remaining = match self.cfg.prefill_chunk {
            0 => usize::MAX,
            n => n,
        };
        let mut out = Vec::with_capacity(ids.len());
        for &id in &ids {
            if self.seq(id).is_some_and(|s| s.prefilled) {
                out.push(PlanItem { seq: id, work: PlanWork::Decode });
            }
        }
        for &id in &ids {
            let Some(s) = self.seq(id) else { continue };
            if s.prefilled || remaining == 0 {
                continue;
            }
            let start = s.prefill_pos;
            let end = s.req.prompt.len().min(start.saturating_add(remaining));
            debug_assert!(end > start, "unprefilled seq {id} has no prompt left");
            remaining = remaining.saturating_sub(end - start);
            out.push(PlanItem { seq: id, work: PlanWork::Prefill { start, end } });
        }
        out
    }

    /// Pop the oldest waiting request without admitting it.  The serving
    /// loop uses this to reject a request that cannot fit the KV pool
    /// even on an empty machine.
    pub fn reject_front(&mut self) -> Option<Request> {
        self.waiting.pop_front()
    }

    /// Remove a not-yet-admitted request from the waiting queue
    /// (cancellation before admission: no pages were ever held).
    pub fn cancel_waiting(&mut self, seq: u64) -> Option<Request> {
        let i = self.waiting.iter().position(|r| r.id == seq)?;
        self.waiting.remove(i)
    }

    /// Record a non-final prefill chunk: prompt tokens up to `end` are
    /// now materialized in KV, but no token was produced yet.
    pub fn on_prefill_chunk(&mut self, seq: u64, end: usize) {
        if let Some(s) = self.seq_mut(seq) {
            debug_assert!(
                end > s.prefill_pos && end < s.req.prompt.len(),
                "chunk end {end} out of range for seq {seq}"
            );
            s.prefill_pos = end;
        }
    }

    /// Record a prefill completion (first token produced).
    pub fn on_prefill_done(&mut self, seq: u64, first_token: u32) {
        if let Some(s) = self.seq_mut(seq) {
            s.prefill_pos = s.req.prompt.len();
            s.prefilled = true;
            s.generated.push(first_token);
        }
    }

    /// Record a successful decode append: advance `ctx`, keep the token.
    fn record_decode(&mut self, seq: u64, token: u32) -> DecodeOutcome {
        let max_seq = self.cfg.max_seq;
        if let Some(s) = self.seq_mut(seq) {
            s.ctx += 1;
            s.generated.push(token);
            if s.done() || s.context_capped(max_seq) {
                return DecodeOutcome::Finished;
            }
        }
        DecodeOutcome::Running
    }

    /// The preemption victim: the NEWEST running sequence that still has
    /// decode work (latest `admitted_s`, ties by request id).  Done or
    /// context-capped residents are never victims — they are about to
    /// retire and their results must still be emitted.
    fn pick_victim(&self) -> Option<u64> {
        let max_seq = self.cfg.max_seq;
        self.running
            .iter()
            .filter(|s| !s.done() && !s.context_capped(max_seq))
            .max_by(|a, b| {
                a.admitted_s
                    .total_cmp(&b.admitted_s)
                    .then(a.req.id.cmp(&b.req.id))
            })
            .map(|s| s.req.id)
    }

    /// Preempt a running sequence to the DDR swap tier: its pages are
    /// freed (token image preserved for a byte-identical resume) and it
    /// joins the `preempted` queue.  Refused (`false`) for unknown,
    /// done, or context-capped sequences.
    pub fn preempt(&mut self, seq: u64) -> bool {
        let max_seq = self.cfg.max_seq;
        let Some(idx) = self.running.iter().position(|s| s.req.id == seq) else {
            return false;
        };
        if self.running[idx].done() || self.running[idx].context_capped(max_seq) {
            return false;
        }
        let s = self.running.swap_remove(idx);
        self.pool
            .swap_out(seq)
            .expect("running sequence is resident in the pool");
        self.preempted.push(s);
        true
    }

    /// Record a decode step.  The KV pool grows first; on exhaustion the
    /// outcome depends on `cfg.swap`: swap OFF reports the sequence for
    /// terminal eviction (legacy truncation), swap ON preempts the
    /// newest resident — possibly the appending sequence itself — and
    /// the decode either completes on the freed pages or resumes later.
    pub fn on_decode_done(&mut self, seq: u64, token: u32) -> DecodeOutcome {
        // The FINAL budgeted token will never be attended to: record it
        // without growing the pool (ctx stays == pool tokens), so a
        // full pool can neither truncate nor pointlessly swap-cycle a
        // request on its very last token.
        let finishes = self
            .seq(seq)
            .is_some_and(|s| s.generated.len() + 1 >= s.req.max_new_tokens as usize);
        if finishes {
            if let Some(s) = self.seq_mut(seq) {
                s.generated.push(token);
            }
            return DecodeOutcome::Finished;
        }
        match self.pool.append(seq) {
            Ok(()) => self.record_decode(seq, token),
            Err(_) if self.cfg.swap => loop {
                let victim = self
                    .pick_victim()
                    .expect("the appending sequence is itself a victim candidate");
                if victim == seq {
                    if self.running.len() == 1 {
                        // Alone on the machine and still out of pages:
                        // ctx + 1 exceeds the ENTIRE pool, so this
                        // sequence can never continue.  Terminal — the
                        // produced token is recorded like the legacy
                        // eviction path.
                        if let Some(s) = self.seq_mut(seq) {
                            s.generated.push(token);
                        }
                        return DecodeOutcome::EvictedKvFull;
                    }
                    // seq is the newest resident with work: it preempts
                    // itself.  The token is DROPPED — the resumed decode
                    // re-produces it at the same position.
                    self.preempt(seq);
                    return DecodeOutcome::Preempted;
                }
                self.preempt(victim);
                if self.pool.append(seq).is_ok() {
                    return self.record_decode(seq, token);
                }
            },
            Err(_) => {
                // The token was produced; record it, but leave ctx equal
                // to the pool's token count and hand the sequence back
                // for retirement.
                if let Some(s) = self.seq_mut(seq) {
                    s.generated.push(token);
                }
                DecodeOutcome::EvictedKvFull
            }
        }
    }

    /// Remove a finished sequence, releasing its pages.  A failed
    /// release means the scheduler and pool disagree about who exists —
    /// a page-leak bug, so it must not pass silently.
    pub fn retire(&mut self, seq: u64) -> Option<SeqState> {
        let idx = self.running.iter().position(|s| s.req.id == seq)?;
        let s = self.running.swap_remove(idx);
        let released = self.pool.release(seq);
        debug_assert!(
            released.is_ok(),
            "retire({seq}): KV release failed: {released:?}"
        );
        Some(s)
    }

    /// Sequences parked in the DDR swap tier, awaiting resume.
    pub fn preempted(&self) -> &[SeqState] {
        &self.preempted
    }

    /// Remove a preempted sequence (client cancellation while parked:
    /// no HBM pages are held, only the swap registry entry).
    pub fn cancel_preempted(&mut self, seq: u64) -> Option<SeqState> {
        let i = self.preempted.iter().position(|s| s.req.id == seq)?;
        let s = self.preempted.swap_remove(i);
        self.pool
            .drop_swapped(seq)
            .expect("preempted sequence is parked in the swap tier");
        Some(s)
    }

    /// Take a parked sequence OFF this lane for cross-shard migration:
    /// returns its full state (token image, prefill progress,
    /// admission time) and clears the local swap-registry entry — no
    /// cancel semantics, no traffic counters; the receiving lane
    /// re-registers it via [`Scheduler::inject_parked`].
    pub(crate) fn take_parked(&mut self, seq: u64) -> Option<SeqState> {
        let i = self.preempted.iter().position(|s| s.req.id == seq)?;
        let s = self.preempted.swap_remove(i);
        self.pool
            .drop_swapped(seq)
            .expect("parked sequence is in the swap registry");
        Some(s)
    }

    /// Inject a parked sequence migrated FROM another lane: its token
    /// footprint joins this pool's swap registry (no traffic counters
    /// — the DDR image was written by the home lane) and the sequence
    /// queues for resume under the usual strict oldest-first order,
    /// `admitted_s` travelling with it.  The later `swap_in` here
    /// counts and prices the read side like any local resume.
    pub(crate) fn inject_parked(&mut self, s: SeqState) {
        debug_assert!(!self.tracks(s.req.id), "sequence {} already on this lane", s.req.id);
        self.pool.register_swapped(s.req.id, s.ctx);
        self.preempted.push(s);
    }

    /// Drain sequences that can never resume (their next decode step
    /// exceeds the entire pool) for terminal eviction by the engine.
    pub fn take_unresumable(&mut self) -> Vec<SeqState> {
        std::mem::take(&mut self.unresumable)
    }

    /// Is this request still anywhere in the scheduler — queued,
    /// running, parked in the swap tier, or awaiting terminal eviction?
    /// `false` means it finished or was cancelled (its id can be
    /// forgotten by routing layers).
    pub fn tracks(&self, seq: u64) -> bool {
        self.running.iter().any(|s| s.req.id == seq)
            || self.waiting.iter().any(|r| r.id == seq)
            || self.preempted.iter().any(|s| s.req.id == seq)
            || self.unresumable.iter().any(|s| s.req.id == seq)
    }

    pub fn is_drained(&self) -> bool {
        self.waiting.is_empty()
            && self.running.is_empty()
            && self.preempted.is_empty()
            && self.unresumable.is_empty()
    }

    /// The scheduler↔pool accounting invariant: every running sequence's
    /// `ctx` equals its pool token count, every preempted sequence's
    /// `ctx` equals its swap-registry token count, and the pool itself
    /// is sound (every page free, retained, or shared with an accurate
    /// refcount).
    pub fn check_accounting(&self) -> bool {
        self.running
            .iter()
            .all(|s| self.pool.seq(s.req.id).is_some_and(|p| p.tokens == s.ctx))
            && self
                .preempted
                .iter()
                .all(|s| self.pool.swapped_tokens(s.req.id) == Some(s.ctx))
            && self.pool.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::workload::{
        generate_shared_prefix_trace, generate_trace, SharedPrefixConfig, TraceConfig,
    };

    fn req(id: u64, plen: usize, dlen: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1; plen],
            max_new_tokens: dlen,
        }
    }

    #[test]
    fn single_batch_runs_one_request_to_completion() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 16, 3));
        s.submit(req(1, 16, 3));
        assert_eq!(s.schedule(0.0), vec![0], "batch=1 admits only request 0");
        s.on_prefill_done(0, 7);
        assert_eq!(s.schedule(0.0), vec![0]);
        assert_eq!(s.on_decode_done(0, 8), DecodeOutcome::Running);
        assert_eq!(s.on_decode_done(0, 9), DecodeOutcome::Finished); // 3 tokens
        s.retire(0);
        assert_eq!(s.schedule(0.0), vec![1]);
        assert!(!s.seq(1).unwrap().prefilled);
    }

    #[test]
    fn multibatch_runs_all_sequences_every_iteration() {
        let mut s = Scheduler::new(SchedulerConfig { max_batch: 2, ..Default::default() });
        s.submit(req(0, 16, 8));
        s.submit(req(1, 16, 8));
        assert_eq!(s.schedule(0.0), vec![0, 1], "both admitted in one iteration");
        s.on_prefill_done(0, 1);
        s.on_prefill_done(1, 1);
        // Continuous batching: every iteration decodes the whole batch.
        assert_eq!(s.schedule(0.0), vec![0, 1]);
    }

    #[test]
    fn admission_gated_by_arrival_time() {
        let mut s = Scheduler::new(SchedulerConfig { max_batch: 2, ..Default::default() });
        let mut r = req(0, 8, 2);
        r.arrival_s = 5.0;
        s.submit(r);
        assert!(s.schedule(0.0).is_empty(), "not arrived yet");
        assert_eq!(s.next_arrival_s(), Some(5.0));
        assert_eq!(s.schedule(5.0), vec![0]);
        assert_eq!(s.seq(0).unwrap().admitted_s, 5.0);
    }

    #[test]
    fn admission_blocked_by_kv_capacity() {
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 2,
            page_tokens: 16,
            max_seq: 256,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 32, 4)); // takes both pages
        s.submit(req(1, 16, 4));
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        // No pages left: request 1 can't be admitted; 0 keeps decoding.
        assert_eq!(s.schedule(0.0), vec![0]);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn context_cap_finishes_sequence() {
        let cfg = SchedulerConfig { max_seq: 18, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 16, 100));
        s.schedule(0.0);
        s.on_prefill_done(0, 1);
        assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // ctx 17
        assert_eq!(s.on_decode_done(0, 3), DecodeOutcome::Finished); // ctx 18
    }

    /// Satellite: `reject_front` pops exactly the head request, touches
    /// no pool state, and leaves the queue serving the next request.
    #[test]
    fn reject_front_pops_head_without_touching_pool() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 8, 2));
        s.submit(req(1, 8, 2));
        let rejected = s.reject_front().expect("head exists");
        assert_eq!(rejected.id, 0);
        assert_eq!(s.pending(), 1);
        assert!(s.running().is_empty());
        assert_eq!(s.pool.used_pages(), 0, "rejection allocates nothing");
        assert!(s.check_accounting());
        assert_eq!(s.schedule(0.0), vec![1], "queue moves on to the next request");
        assert!(s.reject_front().is_none() || s.pending() == 0);
    }

    /// Regression (KV desync): when the pool cannot grow, the sequence is
    /// evicted and `ctx` stays equal to the pool's token count — the old
    /// code pushed the token anyway and stalled with ctx != pool tokens.
    #[test]
    fn kv_exhaustion_evicts_instead_of_desyncing() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 2,
            page_tokens: 4,
            max_seq: 64,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 7, 100)); // 2 pages, 1 token of slack
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // token 8 fills page 2
        assert!(s.check_accounting());
        assert_eq!(s.on_decode_done(0, 3), DecodeOutcome::EvictedKvFull);
        let seq = s.seq(0).unwrap();
        assert_eq!(seq.ctx, 8, "ctx must not advance past the pool");
        assert_eq!(s.pool.seq(0).unwrap().tokens, 8);
        assert_eq!(seq.generated.len(), 3, "produced tokens are kept");
        assert!(s.check_accounting());
        s.retire(0);
        assert_eq!(s.pool.used_pages(), 0);
    }

    /// The final budgeted token is never attended to, so it needs no KV
    /// growth: a pool that is exactly full must complete the request —
    /// not truncate it (swap off) or swap-cycle it (swap on).
    #[test]
    fn final_token_completes_even_when_pool_is_full() {
        for swap in [false, true] {
            let cfg = SchedulerConfig {
                max_batch: 1,
                kv_pages: 2,
                page_tokens: 4,
                max_seq: 64,
                swap,
                ..Default::default()
            };
            let mut s = Scheduler::new(cfg);
            s.submit(req(0, 7, 3)); // ctx 8 fills the pool before the last token
            assert_eq!(s.schedule(0.0), vec![0]);
            s.on_prefill_done(0, 1);
            assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // ctx 7 -> 8
            assert_eq!(
                s.on_decode_done(0, 3),
                DecodeOutcome::Finished,
                "the last token must not need a page (swap = {swap})"
            );
            let seq = s.seq(0).unwrap();
            assert_eq!(seq.generated, vec![1, 2, 3], "full budget delivered");
            assert_eq!(seq.ctx, 8, "ctx still equals pool tokens");
            assert_eq!(s.preempted().len(), 0, "no pointless swap cycle");
            assert!(s.check_accounting());
            s.retire(0);
            assert!(s.is_drained());
        }
    }

    /// Swap mode: KV exhaustion preempts the NEWEST resident (here the
    /// appending sequence itself) instead of truncating it; the oldest
    /// keeps decoding, and once it retires the parked sequence swaps
    /// back in with its token image intact and resumes decoding.
    #[test]
    fn kv_exhaustion_preempts_newest_and_resumes() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            kv_pages: 4,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 8, 4)); // 2 pages
        s.submit(req(1, 4, 100)); // 1 page, wants to grow forever
        assert_eq!(s.schedule(0.0), vec![0, 1]);
        s.on_prefill_done(0, 10);
        s.on_prefill_done(1, 20);
        // Seq 0 takes the last free page; seq 1's growth then exhausts
        // the pool.  Seq 1 is the newest resident → it preempts itself.
        assert_eq!(s.on_decode_done(0, 11), DecodeOutcome::Running);
        assert_eq!(s.on_decode_done(1, 21), DecodeOutcome::Preempted);
        assert!(s.seq(1).is_none(), "parked, not running");
        assert_eq!(s.preempted().len(), 1);
        assert_eq!(
            s.preempted()[0].generated,
            vec![20],
            "the un-appended token is dropped (re-decoded at resume)"
        );
        assert_eq!(s.pool.swapped_tokens(1), Some(4));
        assert!(!s.is_drained(), "a parked sequence keeps the engine alive");
        assert!(s.check_accounting());
        // The oldest request completes untouched on the freed capacity.
        assert_eq!(s.on_decode_done(0, 12), DecodeOutcome::Running);
        assert_eq!(s.on_decode_done(0, 13), DecodeOutcome::Finished);
        s.retire(0);
        // Resume: the swap-in happens inside plan() and the sequence
        // decodes again from exactly where it stopped.
        let plan = s.plan(0.0);
        assert_eq!(plan, vec![PlanItem { seq: 1, work: PlanWork::Decode }]);
        let resumed = s.seq(1).unwrap();
        assert_eq!(resumed.ctx, 4, "context restored");
        assert_eq!(resumed.generated, vec![20], "token image byte-identical");
        assert!(resumed.prefilled);
        assert!(s.check_accounting());
        assert_eq!(s.on_decode_done(1, 21), DecodeOutcome::Running);
        assert_eq!(s.seq(1).unwrap().generated, vec![20, 21]);
    }

    /// Swap mode: when an OLD sequence needs pages, the newest other
    /// resident is the victim — and swap-ins beat fresh admissions to
    /// the freed batch slot.
    #[test]
    fn old_sequence_growth_evicts_newest_victim() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            kv_pages: 4,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 4, 100));
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 10);
        // Seq 1 arrives later: strictly newer.
        let mut r1 = req(1, 12, 100);
        r1.arrival_s = 1.0;
        s.submit(r1);
        assert_eq!(s.schedule(1.0), vec![0, 1]);
        s.on_prefill_done(1, 20);
        // Pool full (1 + 3 pages).  Seq 0's growth preempts seq 1.
        assert_eq!(s.on_decode_done(0, 11), DecodeOutcome::Running);
        assert_eq!(s.running().len(), 1, "victim left the running set");
        assert_eq!(s.preempted().len(), 1);
        assert_eq!(s.preempted()[0].req.id, 1, "newest is the victim");
        assert_eq!(s.seq(0).unwrap().ctx, 5, "the old sequence grew");
        assert!(s.check_accounting());
        // A fresh request is waiting, but the parked sequence takes the
        // freed slot first once seq 0 retires.
        s.submit(req(2, 4, 2));
        s.retire(0);
        let ids = s.schedule(1.0);
        assert_eq!(ids[0], 1, "swap-in beats the fresh admission");
        assert!(s.seq(1).is_some());
        assert_eq!(s.seq(1).unwrap().ctx, 12);
        assert!(s.check_accounting());
    }

    /// Swap mode: a sequence that alone exceeds the entire pool is still
    /// terminally evicted — no amount of swapping can ever resume it.
    #[test]
    fn lone_sequence_exceeding_pool_still_evicts_terminally() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 2,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 7, 100)); // 2 pages, 1 token of slack
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running); // fills the pool
        assert_eq!(s.on_decode_done(0, 3), DecodeOutcome::EvictedKvFull);
        assert_eq!(s.seq(0).unwrap().generated.len(), 3, "produced tokens kept");
        assert!(s.check_accounting());
        s.retire(0);
        assert!(s.is_drained());
    }

    /// A sequence force-preempted while it holds the whole pool can
    /// never swap back in: `plan` routes it to the unresumable drain for
    /// terminal eviction instead of stalling the engine forever.
    #[test]
    fn unresumable_preempted_sequence_is_drained_for_eviction() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 2,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 8, 100)); // exactly the whole pool
        assert_eq!(s.schedule(0.0), vec![0]);
        s.on_prefill_done(0, 1);
        assert!(s.preempt(0), "explicit preemption of a running sequence");
        assert_eq!(s.pool.used_pages(), 0);
        assert!(s.plan(0.0).is_empty());
        let dead = s.take_unresumable();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].req.id, 0);
        assert_eq!(s.pool.swapped_seqs(), 0, "swap registry entry dropped");
        assert!(s.is_drained());
        assert!(s.check_accounting());
    }

    /// Cross-shard migration at the scheduler level: a parked sequence
    /// taken off one scheduler and injected into another resumes there
    /// byte-identically — ctx, prefill progress and generated tokens
    /// intact, accounting holding on BOTH lanes throughout, with no
    /// swap-out traffic counted on the receiving pool.
    #[test]
    fn parked_sequence_migrates_across_schedulers_byte_identically() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 4,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut home = Scheduler::new(cfg.clone());
        home.submit(req(0, 6, 8));
        assert_eq!(home.schedule(0.5), vec![0]);
        home.on_prefill_done(0, 10);
        assert_eq!(home.on_decode_done(0, 11), DecodeOutcome::Running);
        assert!(home.preempt(0), "park the mid-decode sequence");
        assert!(home.check_accounting());
        let parked = home.take_parked(0).expect("parked sequence exports");
        assert!(home.take_parked(0).is_none(), "gone from the home lane");
        assert!(home.is_drained());
        assert!(home.check_accounting());
        assert_eq!(parked.ctx, 7);
        assert_eq!(parked.generated, vec![10, 11]);
        assert_eq!(parked.admitted_s, 0.5, "admission time travels");
        let mut target = Scheduler::new(cfg);
        target.inject_parked(parked);
        assert!(target.tracks(0));
        assert!(target.check_accounting());
        // Resume on the foreign lane: swap-in happens inside plan().
        let plan = target.plan(1.0);
        assert_eq!(plan, vec![PlanItem { seq: 0, work: PlanWork::Decode }]);
        let resumed = target.seq(0).unwrap();
        assert_eq!(resumed.ctx, 7, "context restored on the foreign lane");
        assert_eq!(resumed.generated, vec![10, 11], "token image byte-identical");
        assert!(resumed.prefilled);
        let st = target.pool.stats();
        assert_eq!(st.swapped_in_pages, 2, "read side priced on the target");
        assert_eq!(st.swapped_out_pages, 0, "write side stayed on the home lane");
        assert!(target.check_accounting());
        assert_eq!(target.on_decode_done(0, 12), DecodeOutcome::Running);
        assert_eq!(target.seq(0).unwrap().generated, vec![10, 11, 12]);
    }

    /// Cancellation while parked in the swap tier: the sequence
    /// disappears without touching HBM, and the machine drains.
    #[test]
    fn cancel_preempted_releases_swap_registry() {
        let cfg = SchedulerConfig {
            max_batch: 1,
            kv_pages: 4,
            page_tokens: 4,
            max_seq: 64,
            swap: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 4, 8));
        s.schedule(0.0);
        s.on_prefill_done(0, 1);
        assert!(s.preempt(0));
        let cancelled = s.cancel_preempted(0).expect("parked sequence cancels");
        assert_eq!(cancelled.generated, vec![1], "partial tokens handed back");
        assert!(s.cancel_preempted(0).is_none(), "already gone");
        assert_eq!(s.pool.swapped_seqs(), 0);
        assert!(s.is_drained());
        assert!(s.check_accounting());
    }

    /// Regression (truncation mismatch): an oversized prompt is truncated
    /// once at submit, so admission accounting, the prompt the backend
    /// prefills, and the pool token count all agree.
    #[test]
    fn oversized_prompt_truncated_consistently() {
        let cfg = SchedulerConfig { max_seq: 16, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 40, 4));
        assert_eq!(s.schedule(0.0), vec![0]);
        let seq = s.seq(0).unwrap();
        assert_eq!(seq.req.prompt.len(), 16, "prompt truncated to max_seq");
        assert_eq!(seq.ctx, 16);
        assert_eq!(s.pool.seq(0).unwrap().tokens, 16);
        assert!(seq.context_capped(16), "full-context prompt caps immediately");
        assert!(s.check_accounting());
    }

    /// With prefix caching on, a second admission of the same prompt
    /// charges only the uncached suffix and records `cached_ctx` — while
    /// ctx still equals the pool's full token count.
    #[test]
    fn admission_charges_only_uncached_suffix() {
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_pages: 3,
            page_tokens: 16,
            max_seq: 256,
            prefix_cache: true,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        let prompt: Vec<u32> = (0..32).collect();
        s.submit(Request { id: 0, arrival_s: 0.0, prompt: prompt.clone(), max_new_tokens: 4 });
        s.submit(Request { id: 1, arrival_s: 0.0, prompt, max_new_tokens: 4 });
        // 3 pages serve both 2-page prompts: seq 1 shares seq 0's first
        // page, so only one fresh page is charged.
        assert_eq!(s.schedule(0.0), vec![0, 1]);
        assert_eq!(s.seq(0).unwrap().cached_ctx, 0, "cold cache");
        assert_eq!(s.seq(1).unwrap().cached_ctx, 16, "first page served from cache");
        assert_eq!(s.seq(1).unwrap().ctx, 32, "ctx counts the WHOLE prompt");
        assert_eq!(s.pool.seq(1).unwrap().tokens, 32);
        assert!(s.check_accounting());
    }

    /// Chunked prefill: a 20-token prompt under an 8-token budget runs
    /// as [0,8) [8,16) [16,20); only the final chunk produces a token.
    #[test]
    fn prefill_splits_into_budget_sized_chunks() {
        let cfg = SchedulerConfig { prefill_chunk: 8, max_seq: 64, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 20, 2));
        assert_eq!(
            s.plan(0.0),
            vec![PlanItem { seq: 0, work: PlanWork::Prefill { start: 0, end: 8 } }]
        );
        s.on_prefill_chunk(0, 8);
        assert_eq!(s.seq(0).unwrap().prefill_pos, 8);
        assert!(!s.seq(0).unwrap().prefilled);
        assert_eq!(
            s.plan(0.0),
            vec![PlanItem { seq: 0, work: PlanWork::Prefill { start: 8, end: 16 } }]
        );
        s.on_prefill_chunk(0, 16);
        assert_eq!(
            s.plan(0.0),
            vec![PlanItem { seq: 0, work: PlanWork::Prefill { start: 16, end: 20 } }],
            "final chunk covers the remainder"
        );
        s.on_prefill_done(0, 7);
        assert!(s.seq(0).unwrap().prefilled);
        assert_eq!(s.seq(0).unwrap().prefill_pos, 20);
        assert_eq!(s.plan(0.0), vec![PlanItem { seq: 0, work: PlanWork::Decode }]);
        assert!(s.check_accounting());
    }

    /// Decode priority: while one sequence is mid-prefill, every
    /// prefilled sequence still decodes each iteration — and the decode
    /// items come first in the plan.
    #[test]
    fn decodes_run_alongside_prefill_chunks() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            prefill_chunk: 8,
            max_seq: 128,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 8, 16));
        assert_eq!(
            s.plan(0.0),
            vec![PlanItem { seq: 0, work: PlanWork::Prefill { start: 0, end: 8 } }]
        );
        s.on_prefill_done(0, 1);
        s.submit(req(1, 32, 4));
        // Four iterations of seq 1's prefill, each alongside a decode of
        // seq 0 — the 32-token prompt never stalls the running decode.
        for chunk in 0..4 {
            let plan = s.plan(0.0);
            assert_eq!(plan[0], PlanItem { seq: 0, work: PlanWork::Decode });
            assert_eq!(
                plan[1],
                PlanItem {
                    seq: 1,
                    work: PlanWork::Prefill { start: chunk * 8, end: (chunk + 1) * 8 },
                }
            );
            assert_eq!(s.on_decode_done(0, 2), DecodeOutcome::Running);
            if chunk < 3 {
                s.on_prefill_chunk(1, (chunk + 1) * 8);
            } else {
                s.on_prefill_done(1, 1);
            }
        }
        // Both prefilled: two decode items, no prefill work left.
        let plan = s.plan(0.0);
        assert_eq!(plan.len(), 2);
        assert!(plan.iter().all(|p| p.work == PlanWork::Decode));
        assert!(s.check_accounting());
    }

    /// The per-iteration budget is shared across prefilling sequences in
    /// admission order: the second prompt waits until the first stops
    /// consuming the whole budget.
    #[test]
    fn prefill_budget_is_shared_in_admission_order() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            prefill_chunk: 10,
            max_seq: 64,
            ..Default::default()
        };
        let mut s = Scheduler::new(cfg);
        s.submit(req(0, 16, 2));
        s.submit(req(1, 16, 2));
        let plan = s.plan(0.0);
        assert_eq!(
            plan,
            vec![PlanItem { seq: 0, work: PlanWork::Prefill { start: 0, end: 10 } }],
            "budget exhausted by seq 0: seq 1 gets nothing this iteration"
        );
        s.on_prefill_chunk(0, 10);
        let plan = s.plan(0.0);
        assert_eq!(
            plan,
            vec![
                PlanItem { seq: 0, work: PlanWork::Prefill { start: 10, end: 16 } },
                PlanItem { seq: 1, work: PlanWork::Prefill { start: 0, end: 4 } },
            ],
            "leftover budget flows to the next prefilling sequence"
        );
    }

    /// Chunking composes with prefix caching: the first chunk starts at
    /// `cached_ctx`, so cached pages are never re-run.
    #[test]
    fn chunks_start_after_cached_prefix() {
        let cfg = SchedulerConfig {
            max_batch: 2,
            kv_pages: 8,
            page_tokens: 16,
            max_seq: 256,
            prefix_cache: true,
            prefill_chunk: 24,
            swap: false,
        };
        let mut s = Scheduler::new(cfg);
        let prompt: Vec<u32> = (0..32).collect();
        s.submit(Request { id: 0, arrival_s: 0.0, prompt: prompt.clone(), max_new_tokens: 2 });
        s.submit(Request { id: 1, arrival_s: 0.0, prompt, max_new_tokens: 2 });
        let plan = s.plan(0.0);
        assert_eq!(plan[0], PlanItem { seq: 0, work: PlanWork::Prefill { start: 0, end: 24 } });
        assert!(
            !plan.iter().any(|p| p.seq == 1),
            "budget consumed by the cold admission"
        );
        s.on_prefill_chunk(0, 24);
        let plan = s.plan(0.0);
        assert_eq!(plan[0], PlanItem { seq: 0, work: PlanWork::Prefill { start: 24, end: 32 } });
        assert_eq!(
            plan[1],
            PlanItem { seq: 1, work: PlanWork::Prefill { start: 16, end: 32 } },
            "cached 16-token prefix is skipped: seq 1's first chunk starts there"
        );
        assert_eq!(s.seq(1).unwrap().cached_ctx, 16);
        assert!(s.check_accounting());
    }

    /// Cancellation before admission: the queued request disappears
    /// without ever touching the pool, and later arrivals still run.
    #[test]
    fn cancel_waiting_removes_queued_request() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        s.submit(req(0, 8, 2));
        s.submit(req(1, 8, 2));
        s.submit(req(2, 8, 2));
        assert!(s.cancel_waiting(1).is_some(), "queued request cancelled");
        assert!(s.cancel_waiting(1).is_none(), "already gone");
        assert_eq!(s.pending(), 2);
        assert_eq!(s.pool.used_pages(), 0);
        assert_eq!(s.plan(0.0).len(), 1);
        assert_eq!(s.plan(0.0)[0].seq, 0, "head request unaffected");
        assert!(s.check_accounting());
    }

    #[test]
    fn property_scheduler_never_starves() {
        // Every submitted request eventually completes (or is cancelled)
        // under any interleaving of batch sizes, lengths and chunking.
        proptest::check_with("scheduler liveness", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 1 + r.below(4) as usize,
                kv_pages: 32,
                page_tokens: 8,
                max_seq: 64,
                prefill_chunk: (r.below(4) * 4) as usize,
                ..Default::default()
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_trace(&TraceConfig {
                n_requests: 6,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            drive_to_drain(&mut s, total, r);
        });
    }

    /// The ctx == pool-tokens property, extended to SHARING: a
    /// shared-prefix trace through a prefix-cached scheduler keeps the
    /// accounting invariant (now covering refcounts and retained pages)
    /// on every step, and every request still completes.
    #[test]
    fn property_accounting_holds_under_prefix_sharing() {
        proptest::check_with("prefix-cache scheduler accounting", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 1 + r.below(4) as usize,
                kv_pages: 24 + r.below(24) as usize,
                page_tokens: 8,
                max_seq: 128,
                prefix_cache: true,
                // Randomly chunked prefill: the accounting must hold at
                // any budget, including mid-prompt iterations.
                prefill_chunk: (r.below(3) * 8) as usize,
                swap: false,
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_shared_prefix_trace(&SharedPrefixConfig {
                n_groups: 2,
                prefix_len: 24,
                tail_len_choices: vec![2, 6, 10],
                decode_len_choices: vec![2, 4],
                n_requests: 6,
                rate_per_s: 50.0,
                vocab: 64,
                seed: r.next_u64(),
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            drive_to_drain(&mut s, total, r);
        });
    }

    /// Satellite: random preempt/swap-out/swap-in cycles interleaved
    /// with admits, appends, chunked prefills and cancellations keep the
    /// ctx == pool-tokens invariant (and `check_invariants`) on every
    /// step, resume token streams byte-identically, and still drain
    /// every request.
    #[test]
    fn property_preempt_swap_cycles_keep_accounting() {
        proptest::check_with("preempt/swap scheduler accounting", 64, |r| {
            let cfg = SchedulerConfig {
                max_batch: 2 + r.below(3) as usize,
                kv_pages: 8 + r.below(8) as usize,
                page_tokens: 4,
                max_seq: 96,
                prefix_cache: r.below(2) == 0,
                prefill_chunk: (r.below(3) * 8) as usize,
                swap: true,
            };
            let mut s = Scheduler::new(cfg);
            let trace = generate_trace(&TraceConfig {
                n_requests: 6,
                prompt_len_choices: vec![4, 8, 16],
                decode_len_choices: vec![2, 4, 8],
                seed: r.next_u64(),
                ..Default::default()
            });
            let total = trace.len();
            for t in trace {
                s.submit(t);
            }
            drive_to_drain(&mut s, total, r);
        });
    }

    /// Shared driver for the liveness/accounting properties: run the
    /// scheduler to drain via `plan` (chunk-aware), randomly cancelling
    /// requests mid-prefill, mid-decode, while queued and while parked
    /// in the swap tier — and, in swap mode, randomly force-preempting
    /// running sequences — checking `check_accounting` after EVERY step
    /// and that every observed token stream only ever grows (a resumed
    /// sequence continues byte-identically from its parked image).
    fn drive_to_drain(s: &mut Scheduler, total: usize, r: &mut crate::util::Rng) {
        let mut resolved = 0; // completed or cancelled
        let mut now = 0.0f64;
        // Last observed generated stream per sequence: preempt/resume
        // must only ever APPEND to it.
        let mut streams: std::collections::HashMap<u64, Vec<u32>> =
            std::collections::HashMap::new();
        for _ in 0..10_000 {
            // Random cancellation: a queued request is dropped from the
            // waiting line; a parked one leaves the swap registry; a
            // running one (possibly mid-prefill) is retired, which must
            // release its pages immediately.
            if r.below(8) == 0 {
                let id = r.below(total as u64);
                if s.cancel_waiting(id).is_some() {
                    resolved += 1;
                } else if s.cancel_preempted(id).is_some() {
                    resolved += 1;
                } else if s.seq(id).is_some() {
                    s.retire(id);
                    resolved += 1;
                }
                assert!(s.check_accounting(), "desync after cancellation");
            }
            // Swap mode: force a preemption beyond what pool pressure
            // alone would trigger (no-op for unknown/done sequences).
            if s.cfg.swap && r.below(8) == 0 {
                s.preempt(r.below(total as u64));
                assert!(s.check_accounting(), "desync after forced preemption");
            }
            let plan = s.plan(now);
            // Force-preempted whole-pool residents can never swap back
            // in; plan hands them over for terminal eviction.
            for dead in s.take_unresumable() {
                streams.remove(&dead.req.id);
                resolved += 1;
            }
            assert!(s.check_accounting(), "desync right after admission");
            if plan.is_empty() {
                if s.is_drained() {
                    break;
                }
                let t = s.next_arrival_s().expect("no arrivals but not drained");
                assert!(t > now, "stalled with arrived work");
                now = t;
                continue;
            }
            for item in plan {
                let id = item.seq;
                if s.seq(id).is_none() {
                    // Preempted mid-iteration by an earlier decode's
                    // victim selection (or cancelled): skip its slot.
                    continue;
                }
                match item.work {
                    PlanWork::Prefill { end, .. } => {
                        let plen = s.seq(id).unwrap().req.prompt.len();
                        if end == plen {
                            s.on_prefill_done(id, 1);
                        } else {
                            s.on_prefill_chunk(id, end);
                        }
                    }
                    PlanWork::Decode => match s.on_decode_done(id, 2) {
                        DecodeOutcome::Running | DecodeOutcome::Preempted => {}
                        DecodeOutcome::Finished | DecodeOutcome::EvictedKvFull => {
                            s.retire(id);
                            resolved += 1;
                        }
                    },
                }
                // The core property: scheduler ctx == pool tokens after
                // EVERY step, for every sequence — shared pages and the
                // swap registry included.
                assert!(s.check_accounting(), "ctx/pool desync");
            }
            // Byte-identity across preempt/resume: a sequence's stream
            // only ever extends what was last observed.
            for st in s.running().iter().chain(s.preempted().iter()) {
                let prev = streams.entry(st.req.id).or_default();
                assert!(
                    st.generated.starts_with(prev),
                    "token stream must survive preempt/swap byte-identically"
                );
                *prev = st.generated.clone();
            }
            now += 0.01;
        }
        assert_eq!(resolved, total, "all requests must finish or cancel");
        assert!(s.is_drained());
        assert!(s.pool.check_invariants());
        assert_eq!(s.pool.used_pages(), 0, "cancellation must release pages");
    }
}
