//! manifest.json loader — the contract between aot.py and the runtime:
//! parameter order/shape/dtype/offsets into weights.bin, artifact module
//! signatures, model config, and golden tensor descriptors.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Model architecture as recorded by aot.py (mirrors python TINY config).
#[derive(Debug, Clone)]
pub struct RuntimeModelConfig {
    pub vocab: u64,
    pub dim: u64,
    pub n_layers: u64,
    pub n_heads: u64,
    pub ffn_dim: u64,
    pub max_seq: u64,
    pub nm_m: u64,
    pub nm_n: u64,
    pub quant_group: u64,
    pub attn_block: u64,
}

/// One tensor in weights.bin (or goldens.bin).
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    /// "f32" | "i32" | "u8"
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

fn parse_entry(j: &Json) -> Result<ParamEntry> {
    Ok(ParamEntry {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("param missing name"))?
            .to_string(),
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("param missing dtype"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("param missing shape"))?
            .iter()
            .map(|v| v.as_u64().unwrap_or(0) as usize)
            .collect(),
        offset: j.get("offset").and_then(Json::as_u64).unwrap_or(0) as usize,
        nbytes: j.get("nbytes").and_then(Json::as_u64).unwrap_or(0) as usize,
    })
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: RuntimeModelConfig,
    pub params: Vec<ParamEntry>,
    pub goldens: Vec<ParamEntry>,
    pub prefill_buckets: Vec<u64>,
    pub golden_prefill_bucket: u64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let g = |k: &str| -> Result<u64> {
            cfg.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("config missing {k}"))
        };
        let config = RuntimeModelConfig {
            vocab: g("vocab")?,
            dim: g("dim")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            ffn_dim: g("ffn_dim")?,
            max_seq: g("max_seq")?,
            nm_m: g("nm_m")?,
            nm_n: g("nm_n")?,
            quant_group: g("quant_group")?,
            attn_block: g("attn_block")?,
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        if params.is_empty() {
            bail!("manifest has no params");
        }
        let goldens = j
            .get("goldens")
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(parse_entry).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        let prefill_buckets = j
            .get("prefill_buckets")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_u64).collect())
            .unwrap_or_default();
        let golden_prefill_bucket =
            j.get("golden_prefill_bucket").and_then(Json::as_u64).unwrap_or(0);
        Ok(Self { dir: dir.to_path_buf(), config, params, goldens, prefill_buckets, golden_prefill_bucket })
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// KV-cache dims: (layers, 2, max_seq, heads, head_dim).
    pub fn kv_dims(&self) -> [usize; 5] {
        let c = &self.config;
        [
            c.n_layers as usize,
            2,
            c.max_seq as usize,
            c.n_heads as usize,
            (c.dim / c.n_heads) as usize,
        ]
    }

    pub fn golden(&self, name: &str) -> Result<&ParamEntry> {
        self.goldens
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no golden named {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipped: artifacts/ not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.dim, 256);
        assert!(!m.params.is_empty());
        assert!(m.artifact_path("decode").exists());
        for b in &m.prefill_buckets {
            assert!(m.artifact_path(&format!("prefill_{b}")).exists());
        }
        // Param table must tile weights.bin exactly.
        let total: usize = m.params.iter().map(|p| p.nbytes).sum();
        let file_len = std::fs::metadata(dir.join("weights.bin")).unwrap().len();
        assert_eq!(total as u64, file_len);
        let mut cursor = 0usize;
        for p in &m.params {
            assert_eq!(p.offset, cursor, "params must be contiguous: {}", p.name);
            cursor += p.nbytes;
        }
    }
}
