//! The model runtime: compiled PJRT executables + resident parameters.
//!
//! Parameter literals are loaded once and passed to `execute` per call
//! (the xla crate's literal path; `execute_b` with pre-uploaded buffers
//! trips a size check inside xla_extension 0.5.1's buffer-donation path,
//! see DESIGN.md §Perf).  The per-token cost is the param hand-over plus
//! the KV literal round trip — measured and attacked in the perf pass.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;
use super::weights::load_param_literals;

/// Output of one decode step.
pub struct DecodeOutput {
    pub logits: Vec<f32>,
    /// KV cache literal; hand it to the next step (the executable root is
    /// a packed (logits, kv) tuple, so outputs surface as literals).
    pub kv: Literal,
}

/// Output of a prefill call.
pub struct PrefillOutput {
    pub logits: Vec<f32>,
    pub kv: Literal,
}

/// Parse the ENTRY computation's parameter ordinals from HLO text and
/// verify they match the `Arg_N` logical indices — the contract that lets
/// the runtime pass arguments in manifest order.  (The HLO text parser
/// preserves ordinals; this check catches a regression in that
/// assumption at load time instead of with a garbage execution.)
fn verify_entry_arg_order(hlo_text: &str) -> Result<usize> {
    let entry_at = hlo_text
        .find("\nENTRY ")
        .or_else(|| hlo_text.starts_with("ENTRY ").then_some(0))
        .ok_or_else(|| anyhow!("no ENTRY computation in HLO text"))?;
    let mut count = 0usize;
    for line in hlo_text[entry_at..].lines().skip(1) {
        if line.starts_with('}') {
            break;
        }
        if !line.contains("= ") || !line.contains(" parameter(") {
            continue;
        }
        // e.g. "  %Arg_67.1 = s32[16]{0} parameter(67)"
        let name = line.trim_start().split(" = ").next().unwrap_or("");
        let name = name.trim_start_matches('%');
        let Some(num) = name.strip_prefix("Arg_") else {
            bail!("unexpected entry parameter name {name:?}");
        };
        let arg: String = num.chars().take_while(|c| c.is_ascii_digit()).collect();
        let ord: String = line
            .split(" parameter(")
            .nth(1)
            .unwrap_or("")
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if arg != ord {
            bail!("parameter ordinal mismatch: Arg_{arg} has ordinal {ord}");
        }
        count += 1;
    }
    if count == 0 {
        bail!("ENTRY computation has no parameters");
    }
    Ok(count)
}

pub struct ModelRuntime {
    pub manifest: Manifest,
    #[allow(dead_code)]
    client: PjRtClient,
    decode_exe: PjRtLoadedExecutable,
    prefill_exes: HashMap<u64, PjRtLoadedExecutable>,
    /// Parameter literals, manifest order (the executables' Arg_0..k-1).
    params: Vec<Literal>,
}

impl ModelRuntime {
    /// Load artifacts from `dir`: manifest, weights, all HLO modules.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(to_anyhow)?;
        let n_params = manifest.params.len();
        let compile = |name: &str, extra: usize| -> Result<PjRtLoadedExecutable> {
            let path = manifest.artifact_path(name);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let arity = verify_entry_arg_order(&text)
                .with_context(|| format!("argument order of {}", path.display()))?;
            if arity != n_params + extra {
                bail!(
                    "{name}: module arity {arity} != {} params + {extra} inputs",
                    n_params
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(to_anyhow)
        };
        let decode_exe = compile("decode", 3)?;
        let mut prefill_exes = HashMap::new();
        for &b in &manifest.prefill_buckets {
            prefill_exes.insert(b, compile(&format!("prefill_{b}"), 1)?);
        }
        let params = load_param_literals(&manifest)?;
        Ok(Self { manifest, client, decode_exe, prefill_exes, params })
    }

    /// Smallest bucket that fits `len` prompt tokens.
    pub fn bucket_for(&self, len: usize) -> Result<u64> {
        self.manifest
            .prefill_buckets
            .iter()
            .copied()
            .find(|&b| len as u64 <= b)
            .ok_or_else(|| {
                anyhow!(
                    "prompt of {len} tokens exceeds largest bucket {:?}",
                    self.manifest.prefill_buckets.last()
                )
            })
    }

    /// Run prefill on a prompt (padded to its bucket by repeating the
    /// last token — the length-adaptive reuse of §5.2).
    ///
    /// NOTE: logits come from the bucket's last row, so callers pass
    /// prompts that exactly fill a bucket for golden-exact results, or
    /// accept bucket semantics (the tiny serving demo rounds prompts up).
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOutput> {
        let bucket = self.bucket_for(prompt.len())?;
        let exe = &self.prefill_exes[&bucket];
        let mut padded = prompt.to_vec();
        padded.resize(bucket as usize, *prompt.last().unwrap_or(&0));
        let tokens = Literal::vec1(&padded);
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&tokens);
        let result = exe.execute::<&Literal>(&args).map_err(to_anyhow)?;
        let (logits, kv) = split_outputs(result)?;
        Ok(PrefillOutput { logits, kv })
    }

    /// One decode step: token + KV literal from the previous step + pos.
    pub fn decode(&self, token: i32, kv: &Literal, pos: i32) -> Result<DecodeOutput> {
        let tok = Literal::vec1(&[token]);
        let pos_lit = Literal::scalar(pos);
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&tok);
        args.push(kv);
        args.push(&pos_lit);
        let result = self.decode_exe.execute::<&Literal>(&args).map_err(to_anyhow)?;
        let (logits, kv) = split_outputs(result)?;
        Ok(DecodeOutput { logits, kv })
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    pub fn vocab(&self) -> usize {
        self.manifest.config.vocab as usize
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow!("{e}")
}

/// The runtime as a serving backend: executes batched coordinator steps
/// slot-by-slot through PJRT (the CPU client runs one executable at a
/// time) and reports measured wall seconds as the step cost — so served
/// traces carry real host latencies on the serving clock.  Per-sequence
/// KV literals live here, keyed by sequence id.
///
/// Prefix caching + chunked prefill: the PJRT KV literals are monolithic
/// per sequence (no paged sharing), so the FULL prompt is recomputed at
/// the final prefill chunk regardless of `cached_ctx` — results stay
/// golden-exact — and non-final chunks are free placeholders.  The
/// skipped-token count is still tallied (`cached_tokens_reported`) so
/// serving stats stay comparable with the page-sharing sim backend.
pub struct RuntimeBackend {
    rt: ModelRuntime,
    kv: HashMap<u64, Literal>,
    cached_tokens_reported: u64,
}

impl RuntimeBackend {
    pub fn new(rt: ModelRuntime) -> Self {
        Self { rt, kv: HashMap::new(), cached_tokens_reported: 0 }
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    /// Prompt tokens the scheduler served from its prefix cache, summed
    /// over all prefills (this backend recomputed them anyway).
    pub fn cached_tokens_reported(&self) -> u64 {
        self.cached_tokens_reported
    }
}

impl crate::coordinator::ModelBackend for RuntimeBackend {
    fn step(
        &mut self,
        batch: &[crate::coordinator::SeqSlot],
    ) -> Result<crate::coordinator::StepOutput> {
        use crate::coordinator::SeqWork;
        let t0 = std::time::Instant::now();
        let mut logits = Vec::with_capacity(batch.len());
        for slot in batch {
            match &slot.work {
                SeqWork::Prefill { prompt, cached_ctx, chunk_end, .. } => {
                    // Monolithic KV literals: the FULL prompt runs at
                    // the final chunk (results stay golden-exact), so
                    // earlier chunks cost nothing here and carry no
                    // logits row at all.
                    if *chunk_end < prompt.len() {
                        logits.push(None);
                        continue;
                    }
                    self.cached_tokens_reported += *cached_ctx as u64;
                    let out = self.rt.prefill(prompt)?;
                    self.kv.insert(slot.seq, out.kv);
                    // Real numerics: the full dense row (the compact
                    // Peak form is for synthetic backends only).
                    logits.push(Some(crate::coordinator::Logits::Dense(out.logits)));
                }
                SeqWork::Decode { last, pos } => {
                    let kv = self
                        .kv
                        .get(&slot.seq)
                        .ok_or_else(|| anyhow!("no KV state for sequence {}", slot.seq))?;
                    let out = self.rt.decode(*last, kv, *pos)?;
                    self.kv.insert(slot.seq, out.kv);
                    logits.push(Some(crate::coordinator::Logits::Dense(out.logits)));
                }
            }
        }
        Ok(crate::coordinator::StepOutput {
            logits,
            step_s: t0.elapsed().as_secs_f64(),
        })
    }

    fn release(&mut self, seq: u64) {
        self.kv.remove(&seq);
    }
}

/// The modules are lowered with return_tuple=True: the root is a packed
/// (logits, kv) tuple surfaced as ONE output buffer (see
/// /opt/xla-example/load_hlo.rs) — fetch and decompose it.
fn split_outputs(mut result: Vec<Vec<PjRtBuffer>>) -> Result<(Vec<f32>, Literal)> {
    let outs = result.pop().ok_or_else(|| anyhow!("empty execution result"))?;
    match outs.len() {
        1 => {
            let root = outs[0].to_literal_sync().map_err(to_anyhow)?;
            let (logits, kv) = root.to_tuple2().map_err(to_anyhow)?;
            Ok((logits.to_vec::<f32>().map_err(to_anyhow)?, kv))
        }
        2 => {
            // Some PJRT builds untuple the root — handle that too.
            let mut it = outs.into_iter();
            let logits = it.next().unwrap().to_literal_sync().map_err(to_anyhow)?;
            let kv = it.next().unwrap().to_literal_sync().map_err(to_anyhow)?;
            Ok((logits.to_vec::<f32>().map_err(to_anyhow)?, kv))
        }
        n => bail!("expected 1 packed or 2 untupled outputs, got {n}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_arg_order_accepts_matching_ordinals() {
        let hlo = "HloModule m\n\nENTRY main {\n  %Arg_0.1 = f32[2]{0} parameter(0)\n  %Arg_1.2 = f32[2]{0} parameter(1)\n  ROOT %t = (f32[2]{0}) tuple(%Arg_0.1)\n}\n";
        assert_eq!(verify_entry_arg_order(hlo).unwrap(), 2);
    }

    #[test]
    fn verify_arg_order_rejects_permuted_ordinals() {
        let hlo = "HloModule m\n\nENTRY main {\n  %Arg_1.1 = f32[2]{0} parameter(0)\n  ROOT %t = (f32[2]{0}) tuple(%Arg_1.1)\n}\n";
        assert!(verify_entry_arg_order(hlo).is_err());
    }

    #[test]
    fn verify_arg_order_requires_entry() {
        assert!(verify_entry_arg_order("HloModule m\n").is_err());
    }
}
