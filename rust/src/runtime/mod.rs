//! PJRT runtime (the L3↔L2 bridge): load the HLO-text artifacts emitted
//! by `python/compile/aot.py`, compile them once on the PJRT CPU client,
//! and execute prefill / decode steps from the serving hot path.  Python
//! never runs here — the rust binary is self-contained once
//! `make artifacts` has produced `artifacts/`.

mod manifest;
mod weights;
mod executor;

pub use executor::{DecodeOutput, ModelRuntime, PrefillOutput, RuntimeBackend};
pub use manifest::{Manifest, ParamEntry, RuntimeModelConfig};
pub use weights::load_param_literals;
