//! weights.bin → xla Literals, one per parameter in manifest order.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

use super::manifest::{Manifest, ParamEntry};

pub fn element_type_of(dtype: &str) -> Result<ElementType> {
    Ok(match dtype {
        "f32" => ElementType::F32,
        "i32" => ElementType::S32,
        "u8" => ElementType::U8,
        other => bail!("unsupported dtype {other}"),
    })
}

/// Build one literal from its raw little-endian bytes.
pub fn literal_from_bytes(entry: &ParamEntry, bytes: &[u8]) -> Result<Literal> {
    let ty = element_type_of(&entry.dtype)?;
    let lit = Literal::create_from_shape_and_untyped_data(ty, &entry.shape, bytes)
        .with_context(|| format!("literal for {}", entry.name))?;
    Ok(lit)
}

/// Load every parameter literal in manifest order (the aot.py contract:
/// executables take params first, in exactly this order).
pub fn load_param_literals(m: &Manifest) -> Result<Vec<Literal>> {
    let blob = std::fs::read(m.dir.join("weights.bin"))
        .with_context(|| format!("reading {}/weights.bin", m.dir.display()))?;
    m.params
        .iter()
        .map(|p| {
            let end = p.offset + p.nbytes;
            if end > blob.len() {
                bail!("weights.bin too short for {} ({} > {})", p.name, end, blob.len());
            }
            literal_from_bytes(p, &blob[p.offset..end])
        })
        .collect()
}

/// Load the golden tensors (same format, goldens.bin).
pub fn load_golden_bytes(m: &Manifest) -> Result<Vec<u8>> {
    std::fs::read(m.dir.join("goldens.bin"))
        .with_context(|| format!("reading {}/goldens.bin", m.dir.display()))
}

/// Extract one golden as f32s.
pub fn golden_f32(m: &Manifest, blob: &[u8], name: &str) -> Result<Vec<f32>> {
    let e = m.golden(name)?;
    if e.dtype != "f32" {
        bail!("golden {name} is {}, not f32", e.dtype);
    }
    let raw = &blob[e.offset..e.offset + e.nbytes];
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Extract one golden as i32s.
pub fn golden_i32(m: &Manifest, blob: &[u8], name: &str) -> Result<Vec<i32>> {
    let e = m.golden(name)?;
    if e.dtype != "i32" {
        bail!("golden {name} is {}, not i32", e.dtype);
    }
    let raw = &blob[e.offset..e.offset + e.nbytes];
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_types_map() {
        assert!(matches!(element_type_of("f32").unwrap(), ElementType::F32));
        assert!(matches!(element_type_of("i32").unwrap(), ElementType::S32));
        assert!(matches!(element_type_of("u8").unwrap(), ElementType::U8));
        assert!(element_type_of("f64").is_err());
    }

    #[test]
    fn literal_from_bytes_roundtrip_f32() {
        let entry = ParamEntry {
            name: "t".into(),
            dtype: "f32".into(),
            shape: vec![2, 2],
            offset: 0,
            nbytes: 16,
        };
        let vals = [1.0f32, -2.5, 3.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = literal_from_bytes(&entry, &bytes).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
    }

    #[test]
    fn literal_from_bytes_u8() {
        let entry = ParamEntry {
            name: "packed".into(),
            dtype: "u8".into(),
            shape: vec![4],
            offset: 0,
            nbytes: 4,
        };
        let lit = literal_from_bytes(&entry, &[0x12, 0x34, 0xAB, 0xFF]).unwrap();
        assert_eq!(lit.element_count(), 4);
    }
}
