"""Pure-jnp correctness oracles for the FlightLLM Pallas kernels.

Each function here is the mathematical definition of the corresponding
Pallas kernel (same argument conventions), written with plain jax.numpy so
that pytest/hypothesis can assert_allclose kernel-vs-ref across shape and
sparsity sweeps. These oracles are also what the rust integration tests
compare golden outputs against (dumped by aot.py next to the artifacts).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# N:M sparse matmul (the MPE SpMM / SpMV path)
# ---------------------------------------------------------------------------

def nm_decompress(vals: jnp.ndarray, idx: jnp.ndarray, m: int, k: int) -> jnp.ndarray:
    """Expand an N:M-compressed weight back to its dense (O, K) form.

    vals: (O, G, N) nonzero values, G = K // M groups along the K axis.
    idx:  (O, G, N) int32 position of each nonzero within its M-group.
    """
    o, g, n = vals.shape
    dense = jnp.zeros((o, g, m), vals.dtype)
    oi = jnp.arange(o)[:, None, None]
    gi = jnp.arange(g)[None, :, None]
    dense = dense.at[oi, gi, idx].set(vals)
    return dense.reshape(o, k)


def nm_spmm_ref(x: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray, m: int) -> jnp.ndarray:
    """y = x @ W^T where W is N:M sparse along K.

    x: (B, K) activations; vals/idx: (O, G, N). Returns (B, O).
    """
    k = x.shape[-1]
    w = nm_decompress(vals, idx, m, k)
    return x @ w.T


# ---------------------------------------------------------------------------
# Mixed-precision dequantization + GEMV/GEMM (always-on-chip decode path)
# ---------------------------------------------------------------------------

def int4_unpack(packed: jnp.ndarray) -> jnp.ndarray:
    """Unpack uint8 (…, K//2) into int codes (…, K) in [-8, 7].

    Low nibble first: packed[..., i] = (code[2i+1]+8) << 4 | (code[2i]+8).
    This is the software model of the paper's bit-width expansion unit.
    """
    lo = (packed & 0x0F).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def int4_pack(codes: np.ndarray) -> np.ndarray:
    """numpy inverse of int4_unpack (used by quantizers and tests)."""
    u = (np.asarray(codes) + 8).astype(np.uint8)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return ((hi << 4) | lo).astype(np.uint8)


def dequant_matmul_ref(
    x: jnp.ndarray, packed: jnp.ndarray, scales: jnp.ndarray, group: int
) -> jnp.ndarray:
    """y = x @ W^T with W stored as packed int4 codes + per-group scales.

    x: (B, K); packed: (O, K//2) uint8; scales: (O, K//group) f32.
    w[o, k] = code[o, k] * scales[o, k // group].
    """
    codes = int4_unpack(packed).astype(jnp.float32)  # (O, K)
    w = codes * jnp.repeat(scales, group, axis=-1)
    return x @ w.T


# ---------------------------------------------------------------------------
# Block-sparse attention (SDDMM -> masked softmax -> SpMM)
# ---------------------------------------------------------------------------

def block_mask_to_dense(block_mask: jnp.ndarray, block: int) -> jnp.ndarray:
    """(Nb, Nb) bool block mask -> (N, N) element mask."""
    return jnp.repeat(jnp.repeat(block_mask, block, axis=0), block, axis=1)


def _softmax(scores: jnp.ndarray) -> jnp.ndarray:
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / e.sum(axis=-1, keepdims=True)


def block_attn_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_mask: jnp.ndarray,
    block: int,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Single-head attention under a block-sparse + causal mask.

    q/k/v: (N, d). block_mask: (N//block, N//block) bool, True = keep.
    Rows with no kept key get all-zero output (matches the kernel, which
    skips fully-masked rows rather than producing NaNs).
    """
    n, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / float(np.sqrt(d))
    scores = (q @ k.T) * sm_scale
    mask = block_mask_to_dense(block_mask, block)
    if causal:
        mask = mask & (jnp.arange(n)[:, None] >= jnp.arange(n)[None, :])
    neg = jnp.finfo(scores.dtype).min
    masked = jnp.where(mask, scores, neg)
    row_has_any = mask.any(axis=1, keepdims=True)
    p = jnp.where(row_has_any, _softmax(masked), 0.0)
    return p @ v


# ---------------------------------------------------------------------------
# MISC two-phase ops (the SFU path)
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    return x * (1.0 / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)) * w


def silu_ref(x: jnp.ndarray) -> jnp.ndarray:
    return x * (1.0 / (1.0 + jnp.exp(-x)))
