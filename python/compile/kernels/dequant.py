"""Mixed-precision dequantize + GEMV/GEMM Pallas kernel.

This is the always-on-chip decode hot path of FlightLLM (§4.3): weights are
stored packed at low bit-width in off-chip memory, streamed into on-chip
buffers, expanded to a uniform integer format by the dequantization unit,
and fed to the MPE while the activation vector stays resident on chip.

TPU mapping (DESIGN.md §Hardware-Adaptation): the bit-width expansion unit
becomes an unpack-and-scale prologue *inside* the kernel, before the MXU
contraction — so HBM traffic is the packed 4-bit stream, not the expanded
weights, exactly the property that raises effective bandwidth utilization.

Format:
    packed: (O, K//2) uint8 — two 4-bit codes per byte, low nibble first,
            code value = stored_nibble - 8 in [-8, 7]
    scales: (O, K//group) f32 — per-(row, group) quantization scale

Correctness: ref.dequant_matmul_ref via python/tests/test_dequant.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_kernel(x_ref, packed_ref, scales_ref, o_ref, *, group: int):
    """One O-tile of y = x @ W^T with in-kernel int4 dequantization.

    x_ref:      (B, K)          VMEM-resident activations
    packed_ref: (O_t, K//2)     packed weight tile (the HBM stream)
    scales_ref: (O_t, K//group) per-group scales
    o_ref:      (B, O_t)
    """
    x = x_ref[...]
    packed = packed_ref[...]
    scales = scales_ref[...]
    o_t = packed.shape[0]
    k = x.shape[1]
    # Bit-width expansion unit: uint8 -> two int4 codes -> int8 lane.
    lo = (packed & 0x0F).astype(jnp.int32) - 8
    hi = (packed >> 4).astype(jnp.int32) - 8
    codes = jnp.stack([lo, hi], axis=-1).reshape(o_t, k).astype(jnp.float32)
    # Scale expansion (per-group scale broadcast across the group).
    w = codes.reshape(o_t, k // group, group) * scales[..., None]
    w = w.reshape(o_t, k)
    o_ref[...] = jnp.dot(
        x, w.T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "block_o"))
def dequant_matmul(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scales: jnp.ndarray,
    group: int = 64,
    block_o: int = 128,
) -> jnp.ndarray:
    """y = x @ W^T, W stored as int4 codes + per-group scales.

    x: (B, K); packed: (O, K//2) uint8; scales: (O, K//group) f32.
    """
    b, k = x.shape
    o, kp = packed.shape
    assert kp * 2 == k, f"packed K mismatch: {kp}*2 != {k}"
    assert k % group == 0
    assert o % block_o == 0, f"O={o} not a multiple of block_o={block_o}"
    grid = (o // block_o,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((block_o, kp), lambda i: (i, 0)),
            pl.BlockSpec((block_o, k // group), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_o), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=True,
    )(x, packed, scales)


def quantize_int4(w, group: int = 64):
    """Symmetric per-group int4 quantization of a dense (O, K) weight
    (numpy, build-time).  Returns (packed uint8 (O,K//2),
    scales f32 (O,K//group)).
    """
    import numpy as np

    w = np.asarray(w, dtype=np.float32)
    o, k = w.shape
    assert k % group == 0 and k % 2 == 0
    wg = w.reshape(o, k // group, group)
    amax = np.abs(wg).max(axis=-1)
    scales = np.where(amax > 0, amax / 7.0, 1.0).astype(np.float32)
    codes = np.clip(np.round(wg / scales[..., None]), -8, 7).astype(np.int8)
    codes = codes.reshape(o, k)
    u = (codes.astype(np.int16) + 8).astype(np.uint8)
    packed = ((u[:, 1::2] << 4) | u[:, 0::2]).astype(np.uint8)
    return packed, scales
