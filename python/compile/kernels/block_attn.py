"""Block-sparse attention Pallas kernel — FlightLLM's fused prefill path.

Paper (§4.2): sparse prefill attention is three steps — SDDMM (QK^T under a
block mask), masked softmax, and SpMM (S·V) — fused so that blocks fully
covered by the zero mask skip their LD + MM entirely and the S matrix never
round-trips through off-chip memory.

TPU mapping (DESIGN.md §Hardware-Adaptation): a flash-attention-style grid
over 64x64 score blocks.  The query block stays VMEM-resident across the
whole key loop (always-on-chip), the online-softmax accumulator replaces
the global buffer, and masked blocks contribute nothing — `where`-masked in
interpret mode, grid-skipped on real hardware.

Correctness: ref.block_attn_ref via python/tests/test_block_attn.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _block_attn_kernel(
    q_ref, k_ref, v_ref, mask_ref, o_ref, *, block: int, causal: bool, sm_scale: float
):
    """One query block of flash-style block-sparse attention.

    q_ref:    (Bq, d)        this query block
    k_ref:    (N, d)         all keys   (streamed block-by-block below)
    v_ref:    (N, d)         all values
    mask_ref: (1, Nb)        this query block's row of the block mask
    o_ref:    (Bq, d)
    """
    qi = pl.program_id(0)
    q = q_ref[...] * sm_scale
    n, d = k_ref.shape
    nb = n // block
    # Large-negative instead of finfo.min so that exp(neg - neg) in a fully
    # masked block can be detected and zeroed rather than becoming exp(0)=1.
    neg = -1e30

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k_blk = k_ref[pl.dslice(j * block, block), :]           # (Bk, d)
        v_blk = v_ref[pl.dslice(j * block, block), :]
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        # Block mask: the SDDMM skip. A masked block contributes -inf scores.
        keep = mask_ref[0, j]
        if causal:
            rows = qi * block + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            cols = j * block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, neg)
        s = jnp.where(keep, s, neg)
        # Online softmax update (the fused softmax of §4.2).
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        # Masked entries contribute exactly 0 even when the whole row is
        # masked (m_cur == neg would make exp(s - m_cur) == 1 otherwise).
        p = jnp.where(s > 0.5 * neg, jnp.exp(s - m_cur[:, None]), 0.0)
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return acc, m_cur, l_cur

    bq = q.shape[0]
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), neg, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # Causal: key blocks beyond the diagonal are always fully masked — the
    # compiler's instruction stream simply doesn't emit them.  Here the loop
    # bound realizes the same skip.
    upper = (qi + 1) if causal else nb
    acc, m_fin, l_fin = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    # Rows with no surviving key (fully masked) produce 0, matching ref.
    safe_l = jnp.where(l_fin > 0, l_fin, 1.0)
    out = jnp.where((l_fin > 0)[:, None], acc / safe_l[:, None], 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "causal", "sm_scale"))
def block_attn(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    block_mask: jnp.ndarray,
    block: int = 64,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Single-head block-sparse attention, out = softmax(QK^T ∘ M) V.

    q/k/v: (N, d) with N a multiple of `block`;
    block_mask: (N//block, N//block) bool, True = compute the block.
    """
    n, d = q.shape
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    nb = n // block
    if sm_scale is None:
        sm_scale = 1.0 / float(np.sqrt(d))
    grid = (nb,)
    return pl.pallas_call(
        functools.partial(
            _block_attn_kernel, block=block, causal=causal, sm_scale=sm_scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(q, k, v, block_mask)


def make_sliding_block_mask(nb: int, window: int = 4, global_blocks: int = 1):
    """Build the paper-style sparse-attention block mask (numpy): sliding
    window of `window` block-diagonals plus `global_blocks` leading global
    columns/rows (the BigBird/Longformer-style pattern cited in §2.2).
    """
    m = np.zeros((nb, nb), dtype=bool)
    for i in range(nb):
        lo = max(0, i - window + 1)
        m[i, lo : i + 1] = True
    m[:, :global_blocks] = True
    m[:global_blocks, :] = True
    # Causal upper triangle is zeroed by the kernel; keep the mask lower.
    return np.tril(m)
