"""L1 Pallas kernels: FlightLLM's compute hot-spots, TPU-adapted.

- nm_sparse:  N:M weight-sparse matmul (CSD-chain SpMM/SpMV path)
- dequant:    mixed-precision int4 dequantize fused into GEMV/GEMM
- block_attn: block-sparse flash attention (fused SDDMM/softmax/SpMM)
- ref:        pure-jnp oracles for all of the above
"""

from .block_attn import block_attn, make_sliding_block_mask
from .dequant import dequant_matmul, quantize_int4
from .nm_sparse import nm_compress, nm_spmm

__all__ = [
    "block_attn",
    "make_sliding_block_mask",
    "dequant_matmul",
    "quantize_int4",
    "nm_compress",
    "nm_spmm",
]
