"""N:M sparse matmul Pallas kernel — the MPE SpMM/SpMV path of FlightLLM.

Paper mapping (DESIGN.md §Hardware-Adaptation): the CSD-chain's sparse MUX
selects, per DSP group, the activation element matching each stored nonzero
index so the MACs only ever see nonzeros.  On TPU the same property is
expressed as *gather-then-dense-contract*: the N:M-compressed weight tile
(vals) is contracted against an activation tile gathered by the stored
indices, so the MXU-bound contraction has length G*N instead of K.

Format (uniform N:M along K, M a power of two, matching the paper's 16x16
sparse block with M=16):
    vals: (O, G, N) f32      nonzero values, G = K // M
    idx:  (O, G, N) int32    position of each nonzero inside its M-group

The kernel is tiled over the output dimension O; the full activation block
(B, K) is VMEM-resident, which is exactly the always-on-chip decode
property for B=1 (x is a vector that never leaves the chip).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom call the
CPU PJRT plugin cannot execute.  Correctness is asserted against
ref.nm_spmm_ref by python/tests/test_nm_sparse.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nm_spmm_kernel(x_ref, vals_ref, idx_ref, o_ref, *, m: int):
    """One O-tile of y = x @ W^T, W given as (vals, idx) N:M compression.

    x_ref:    (B, K)        full activation block (VMEM resident)
    vals_ref: (O_t, G, N)   weight-nonzero tile streamed from HBM
    idx_ref:  (O_t, G, N)   matching in-group indices
    o_ref:    (B, O_t)      output tile
    """
    x = x_ref[...]
    vals = vals_ref[...]
    idx = idx_ref[...]
    b = x.shape[0]
    o_t, g, n = vals.shape
    # Regroup activations by M-group: (B, G, M).
    x_g = x.reshape(b, g, m)
    # Sparse-MUX equivalent: gather the activation matching each nonzero.
    # x_sel[b, o, gi, ni] = x_g[b, gi, idx[o, gi, ni]]
    gi = jax.lax.broadcasted_iota(jnp.int32, (o_t, g, n), 1)
    x_sel = x_g[:, gi, idx]                       # (B, O_t, G, N)
    # Dense contraction over the compressed axis (the MXU-friendly part).
    acc = jnp.einsum(
        "bogn,ogn->bo", x_sel, vals, preferred_element_type=jnp.float32
    )
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "block_o"))
def nm_spmm(
    x: jnp.ndarray,
    vals: jnp.ndarray,
    idx: jnp.ndarray,
    m: int,
    block_o: int = 128,
) -> jnp.ndarray:
    """y = x @ W^T with W N:M sparse along K.

    x: (B, K); vals/idx: (O, G, N) with G = K // M.  Returns (B, O) f32.
    block_o must divide O (pad O to a multiple upstream; the compiler's
    shape legalizer guarantees this for real model layers).
    """
    b, k = x.shape
    o, g, n = vals.shape
    assert g * m == k, f"K mismatch: {g}*{m} != {k}"
    assert o % block_o == 0, f"O={o} not a multiple of block_o={block_o}"
    grid = (o // block_o,)
    return pl.pallas_call(
        functools.partial(_nm_spmm_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((block_o, g, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_o, g, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_o), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=True,
    )(x, vals, idx)


def nm_compress(w, m: int, n: int):
    """Compress a dense (O, K) weight to N:M format, keeping the N
    largest-magnitude entries per M-group (numpy, build-time only).

    Returns (vals (O,G,N) f32, idx (O,G,N) int32) with idx sorted ascending
    inside each group — the canonical order the hardware index buffer uses.
    """
    import numpy as np

    w = np.asarray(w, dtype=np.float32)
    o, k = w.shape
    assert k % m == 0
    g = k // m
    wg = w.reshape(o, g, m)
    order = np.argsort(-np.abs(wg), axis=-1)[..., :n]  # top-N per group
    idx = np.sort(order, axis=-1).astype(np.int32)
    vals = np.take_along_axis(wg, idx, axis=-1).astype(np.float32)
    return vals, idx
