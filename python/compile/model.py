"""L2: LLaMA-architecture transformer whose compressed linears call the L1
Pallas kernels — FlightLLM's compute graph, authored in JAX at build time.

Two lowering entry points (see aot.py):

- ``prefill(params, tokens)``      — one HLO module per token-length bucket
  (the length-adaptive compilation of §5.2: lengths inside a bucket share
  the same instructions / here the same executable).
- ``decode_step(params, token, kv, pos)`` — a single fused module for one
  decode iteration: every layer's compute chained with no host round-trip,
  the *always-on-chip decode* of §4 (activations live in the executable's
  private buffers; only weights/KV stream in).

Compression mirrors the paper's recipe (§6.2.1): N:M pruning on the
attention projections (the CSD-chain SpMM path), int4 per-group
quantization on the FFN matrices (the mixed-precision dequant path), and
block-sparse attention for prefill (the fused SDDMM path).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import block_attn, dequant_matmul, nm_compress, nm_spmm, quantize_int4
from .kernels.dequant import quantize_int4 as _q4  # noqa: F401 (re-export)
from .kernels.ref import rmsnorm_ref, silu_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-LLaMA architecture + compression hyper-parameters.

    The 7B-scale configs (``llama2_7b``/``opt_6_7b`` in rust/src/config/)
    drive the simulator analytically; this config is the *runnable* model.
    """

    vocab: int = 512
    dim: int = 256
    n_layers: int = 4
    n_heads: int = 8
    ffn_dim: int = 512
    max_seq: int = 256
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # compression
    nm_m: int = 16          # N:M sparsity: M (group size along K)
    nm_n: int = 8           # N kept per group on attention projections
    quant_group: int = 64   # int4 group size on FFN weights
    attn_block: int = 16    # block-sparse attention block (paper: 64)
    attn_window: int = 4    # sliding-window width in blocks
    attn_global: int = 1    # leading global blocks

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads


TINY = ModelConfig()


# ---------------------------------------------------------------------------
# Parameter init / dense forward (training + PPL oracle path)
# ---------------------------------------------------------------------------

def init_params(rng: np.random.Generator, cfg: ModelConfig) -> dict[str, Any]:
    """Dense fp32 parameters (numpy dict keyed by flat names)."""

    def lin(o, k, scale=None):
        s = scale if scale is not None else (1.0 / np.sqrt(k))
        return (rng.standard_normal((o, k)) * s).astype(np.float32)

    p: dict[str, Any] = {
        "embed": (rng.standard_normal((cfg.vocab, cfg.dim)) * 0.02).astype(
            np.float32
        ),
        "head": lin(cfg.vocab, cfg.dim),
        "norm_f": np.ones(cfg.dim, np.float32),
    }
    for i in range(cfg.n_layers):
        p[f"l{i}.wq"] = lin(cfg.dim, cfg.dim)
        p[f"l{i}.wk"] = lin(cfg.dim, cfg.dim)
        p[f"l{i}.wv"] = lin(cfg.dim, cfg.dim)
        p[f"l{i}.wo"] = lin(cfg.dim, cfg.dim)
        p[f"l{i}.w1"] = lin(cfg.ffn_dim, cfg.dim)
        p[f"l{i}.w3"] = lin(cfg.ffn_dim, cfg.dim)
        p[f"l{i}.w2"] = lin(cfg.dim, cfg.ffn_dim)
        p[f"l{i}.norm_attn"] = np.ones(cfg.dim, np.float32)
        p[f"l{i}.norm_ffn"] = np.ones(cfg.dim, np.float32)
    return p


def rope_angles(cfg: ModelConfig, positions: jnp.ndarray):
    """cos/sin tables for the given positions: (L, head_dim/2) each.

    inv_freq is a trace-time numpy constant: computing it with jnp.power
    emits a `power` HLO whose constant folding differs between jax's CPU
    backend and the xla_extension 0.5.1 runtime the rust side uses —
    baking the constant keeps the two bit-identical.
    """
    hd = cfg.head_dim
    inv = jnp.asarray(
        1.0 / (cfg.rope_theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd)),
        dtype=jnp.float32,
    )
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (L, H, hd) — rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[:, None, :]
    s = sin[:, None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape)


def dense_forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Uncompressed forward over a full sequence. tokens: (L,) int32 ->
    logits (L, vocab). Used for training and as the PPL 'None' baseline."""
    L = tokens.shape[0]
    x = params["embed"][tokens]
    pos = jnp.arange(L)
    cos, sin = rope_angles(cfg, pos)
    causal = jnp.tril(jnp.ones((L, L), bool))
    for i in range(cfg.n_layers):
        h = rmsnorm_ref(x, params[f"l{i}.norm_attn"], cfg.norm_eps)
        q = (h @ params[f"l{i}.wq"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        k = (h @ params[f"l{i}.wk"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        v = (h @ params[f"l{i}.wv"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(cfg.head_dim)
        scores = jnp.where(causal[None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v).reshape(L, cfg.dim)
        x = x + o @ params[f"l{i}.wo"].T
        h = rmsnorm_ref(x, params[f"l{i}.norm_ffn"], cfg.norm_eps)
        gate = silu_ref(h @ params[f"l{i}.w1"].T)
        up = h @ params[f"l{i}.w3"].T
        x = x + (gate * up) @ params[f"l{i}.w2"].T
    x = rmsnorm_ref(x, params["norm_f"], cfg.norm_eps)
    return x @ params["head"].T


# ---------------------------------------------------------------------------
# Compression (build-time; mirrors rust/src/{sparse,quant} semantics)
# ---------------------------------------------------------------------------

NM_KEYS = ("wq", "wk", "wv", "wo")   # CSD-chain SpMM path
Q4_KEYS = ("w1", "w2", "w3")         # mixed-precision dequant path


def compress_params(params: dict, cfg: ModelConfig) -> dict[str, Any]:
    """Dense params -> compressed params consumed by the kernel model.

    Attention projections become (vals, idx) N:M pairs; FFN matrices become
    (packed, scales) int4 pairs; everything else passes through fp32.
    """
    out: dict[str, Any] = {}
    for name, w in params.items():
        suffix = name.split(".")[-1]
        if suffix in NM_KEYS:
            vals, idx = nm_compress(w, cfg.nm_m, cfg.nm_n)
            out[name + ".vals"] = vals
            out[name + ".idx"] = idx
        elif suffix in Q4_KEYS:
            packed, scales = quantize_int4(w, cfg.quant_group)
            out[name + ".packed"] = packed
            out[name + ".scales"] = scales
        else:
            out[name] = np.asarray(w, np.float32)
    return out


def param_order(cfg: ModelConfig) -> list[str]:
    """Canonical flattening order of compressed params — the contract
    between aot.py's manifest/weights.bin and the rust runtime."""
    names = ["embed", "head", "norm_f"]
    for i in range(cfg.n_layers):
        for kk in NM_KEYS:
            names += [f"l{i}.{kk}.vals", f"l{i}.{kk}.idx"]
        for kk in Q4_KEYS:
            names += [f"l{i}.{kk}.packed", f"l{i}.{kk}.scales"]
        names += [f"l{i}.norm_attn", f"l{i}.norm_ffn"]
    return names


def _lin_nm(cp: dict, name: str, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    o = cp[name + ".vals"].shape[0]
    return nm_spmm(x, cp[name + ".vals"], cp[name + ".idx"], cfg.nm_m,
                   block_o=min(128, o))


def _lin_q4(cp: dict, name: str, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    o = cp[name + ".packed"].shape[0]
    return dequant_matmul(x, cp[name + ".packed"], cp[name + ".scales"],
                          group=cfg.quant_group, block_o=min(128, o))


def make_block_mask(cfg: ModelConfig, n: int) -> np.ndarray:
    from .kernels import make_sliding_block_mask

    nb = n // cfg.attn_block
    return make_sliding_block_mask(nb, cfg.attn_window, cfg.attn_global)


# ---------------------------------------------------------------------------
# Compressed prefill (one module per token bucket)
# ---------------------------------------------------------------------------

def prefill(cp: dict, cfg: ModelConfig, tokens: jnp.ndarray):
    """tokens: (L,) int32, L a bucket length (multiple of attn_block).

    Returns (logits (1, vocab) for the last position,
             kv (n_layers, 2, max_seq, n_heads, head_dim) zero-padded).
    """
    L = tokens.shape[0]
    x = cp["embed"][tokens]
    pos = jnp.arange(L)
    cos, sin = rope_angles(cfg, pos)
    mask = jnp.asarray(make_block_mask(cfg, L))
    kv_layers = []
    for i in range(cfg.n_layers):
        h = rmsnorm_ref(x, cp[f"l{i}.norm_attn"], cfg.norm_eps)
        q = _lin_nm(cp, f"l{i}.wq", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        k = _lin_nm(cp, f"l{i}.wk", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        v = _lin_nm(cp, f"l{i}.wv", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        # Block-sparse fused attention per head (§4.2 fused prefill path).
        heads = [
            block_attn(q[:, hh], k[:, hh], v[:, hh], mask,
                       block=cfg.attn_block)
            for hh in range(cfg.n_heads)
        ]
        o = jnp.stack(heads, axis=1).reshape(L, cfg.dim)
        x = x + _lin_nm(cp, f"l{i}.wo", o, cfg)
        h = rmsnorm_ref(x, cp[f"l{i}.norm_ffn"], cfg.norm_eps)
        gate = silu_ref(_lin_q4(cp, f"l{i}.w1", h, cfg))
        up = _lin_q4(cp, f"l{i}.w3", h, cfg)
        x = x + _lin_q4(cp, f"l{i}.w2", gate * up, cfg)
        pad = cfg.max_seq - L
        k_pad = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
        v_pad = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        kv_layers.append(jnp.stack([k_pad, v_pad]))
    kv = jnp.stack(kv_layers)  # (layers, 2, max_seq, heads, hd)
    x_last = rmsnorm_ref(x[-1:], cp["norm_f"], cfg.norm_eps)
    logits = x_last @ cp["head"].T
    return logits, kv


# ---------------------------------------------------------------------------
# Compressed decode step (the always-on-chip fused module)
# ---------------------------------------------------------------------------

def decode_step(cp: dict, cfg: ModelConfig, token: jnp.ndarray,
                kv: jnp.ndarray, pos: jnp.ndarray):
    """One decode iteration.

    token: (1,) int32 — the last generated token.
    kv:    (n_layers, 2, max_seq, n_heads, head_dim) f32.
    pos:   () int32 — number of tokens already in the cache.

    Returns (logits (1, vocab), updated kv).  All intermediate activations
    stay inside this one module: the always-on-chip decode scheme.
    """
    x = cp["embed"][token]  # (1, dim)
    cos, sin = rope_angles(cfg, pos[None].astype(jnp.float32))
    valid = (jnp.arange(cfg.max_seq) <= pos)[None, :]  # (1, max_seq)
    new_kv = []
    for i in range(cfg.n_layers):
        h = rmsnorm_ref(x, cp[f"l{i}.norm_attn"], cfg.norm_eps)
        q = _lin_nm(cp, f"l{i}.wq", h, cfg).reshape(1, cfg.n_heads, cfg.head_dim)
        k = _lin_nm(cp, f"l{i}.wk", h, cfg).reshape(1, cfg.n_heads, cfg.head_dim)
        v = _lin_nm(cp, f"l{i}.wv", h, cfg).reshape(1, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        k_cache = jax.lax.dynamic_update_slice(
            kv[i, 0], k, (pos, jnp.int32(0), jnp.int32(0))
        )
        v_cache = jax.lax.dynamic_update_slice(
            kv[i, 1], v, (pos, jnp.int32(0), jnp.int32(0))
        )
        # MV-mode attention: q (1,H,hd) against the whole cache, masked to
        # positions <= pos (the MPE GEMV path of §3.2.2).
        scores = jnp.einsum("qhd,khd->hqk", q, k_cache) / np.sqrt(cfg.head_dim)
        scores = jnp.where(valid[None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v_cache).reshape(1, cfg.dim)
        x = x + _lin_nm(cp, f"l{i}.wo", o, cfg)
        h = rmsnorm_ref(x, cp[f"l{i}.norm_ffn"], cfg.norm_eps)
        gate = silu_ref(_lin_q4(cp, f"l{i}.w1", h, cfg))
        up = _lin_q4(cp, f"l{i}.w3", h, cfg)
        x = x + _lin_q4(cp, f"l{i}.w2", gate * up, cfg)
        new_kv.append(jnp.stack([k_cache, v_cache]))
    kv_out = jnp.stack(new_kv)
    x = rmsnorm_ref(x, cp["norm_f"], cfg.norm_eps)
    logits = x @ cp["head"].T
    return logits, kv_out


# ---------------------------------------------------------------------------
# Compressed full-sequence forward (PPL evaluation of compressed configs)
# ---------------------------------------------------------------------------

def compressed_forward(cp: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence logits under compression, dense attention math but
    compressed linears + block-sparse attention mask (Table 4's 'All').

    Sequences are padded to a multiple of attn_block (causality keeps the
    padding from affecting real positions) and sliced back.
    """
    orig_len = tokens.shape[0]
    pad = (-orig_len) % cfg.attn_block
    if pad:
        tokens = jnp.pad(tokens, (0, pad))
    L = tokens.shape[0]
    x = cp["embed"][tokens]
    pos = jnp.arange(L)
    cos, sin = rope_angles(cfg, pos)
    from .kernels.ref import block_attn_ref  # noqa: F401

    mask_blocks = jnp.asarray(make_block_mask(cfg, L))
    from .kernels.ref import block_mask_to_dense

    mask = block_mask_to_dense(mask_blocks, cfg.attn_block)
    mask = mask & jnp.tril(jnp.ones((L, L), bool))
    for i in range(cfg.n_layers):
        h = rmsnorm_ref(x, cp[f"l{i}.norm_attn"], cfg.norm_eps)
        q = _lin_nm(cp, f"l{i}.wq", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        k = _lin_nm(cp, f"l{i}.wk", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        v = _lin_nm(cp, f"l{i}.wv", h, cfg).reshape(L, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(cfg.head_dim)
        scores = jnp.where(mask[None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v).reshape(L, cfg.dim)
        x = x + _lin_nm(cp, f"l{i}.wo", o, cfg)
        h = rmsnorm_ref(x, cp[f"l{i}.norm_ffn"], cfg.norm_eps)
        gate = silu_ref(_lin_q4(cp, f"l{i}.w1", h, cfg))
        up = _lin_q4(cp, f"l{i}.w3", h, cfg)
        x = x + _lin_q4(cp, f"l{i}.w2", gate * up, cfg)
    x = rmsnorm_ref(x, cp["norm_f"], cfg.norm_eps)
    logits = x @ cp["head"].T
    return logits[:orig_len]
