"""Table 4 reproduction: perplexity of the tiny model under the paper's
compression configurations (None / Sparse Attention / Weight Pruning /
Quantization / All) on the held-out synthetic corpus.

The paper measures LLaMA2-7B / OPT-6.7B on WikiText; we measure the tiny
trained model on the synthetic held-out split (DESIGN.md §Substitutions).
The reproduction target is the *structure*: every single technique costs
little perplexity, the combination costs slightly more, and nothing
diverges.

Run: python -m compile.eval_ppl [--out ../artifacts/table4.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import make_corpus, split_corpus
from .model import (
    TINY,
    ModelConfig,
    compress_params,
    compressed_forward,
    dense_forward,
    init_params,
)
from .train import DEFAULT_OUT as PARAMS_FILE


def perplexity(forward, tokens: np.ndarray, seq_len: int = 128, max_windows: int = 16) -> float:
    """Sliding-window next-token perplexity."""
    nlls = []
    count = 0
    n_windows = min(max_windows, (len(tokens) - 1) // seq_len)
    for w in range(n_windows):
        chunk = tokens[w * seq_len : w * seq_len + seq_len + 1]
        logits = forward(jnp.asarray(chunk[:-1]))
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = chunk[1:]
        nll = -np.asarray(logp)[np.arange(seq_len), tgt]
        nlls.append(nll.sum())
        count += seq_len
    return float(np.exp(np.sum(nlls) / count))


def config_variants(base: ModelConfig) -> dict[str, dict]:
    """The five Table 4 rows, expressed as compression-knob overrides.

    'off' for a technique = lossless settings (N=M keeps all weights,
    8-bit→identity is not available so quantization-off uses the dense
    weights directly — handled via masking in `evaluate`).
    """
    return {
        "None": dict(sparse_attn=False, pruning=False, quant=False),
        "Sparse Attention": dict(sparse_attn=True, pruning=False, quant=False),
        "Weight Pruning": dict(sparse_attn=False, pruning=True, quant=False),
        "Quantization": dict(sparse_attn=False, pruning=False, quant=True),
        "All": dict(sparse_attn=True, pruning=True, quant=True),
    }


def evaluate(params, base_cfg: ModelConfig, holdout: np.ndarray) -> dict[str, float]:
    results: dict[str, float] = {}
    for name, knobs in config_variants(base_cfg).items():
        cfg = dataclasses.replace(
            base_cfg,
            # pruning off → keep all (N = M); on → paper-style N = M/2.
            nm_n=(base_cfg.nm_m if not knobs["pruning"] else base_cfg.nm_m // 2),
            # sparse attention off → window covering the whole sequence.
            attn_window=(10_000 if not knobs["sparse_attn"] else base_cfg.attn_window),
        )
        if knobs["quant"] or knobs["pruning"] or knobs["sparse_attn"]:
            cp = compress_params(params, cfg)
            if not knobs["quant"]:
                # Undo quantization loss: rebuild exact packed weights is
                # impossible (int4 is lossy), so for the quant-off rows we
                # replace the FFN tensors with a fresh quantization at the
                # tightest group size... no — instead evaluate with the
                # dense FFN by quantizing with per-column scales at 4 bit
                # would still be lossy. We instead bypass: use the dense
                # forward path restricted to the enabled techniques.
                ppl = perplexity(
                    lambda t, cp=cp, cfg=cfg: _mixed_forward(
                        params, cp, cfg, t, use_quant=False,
                        use_prune=knobs["pruning"], use_sattn=knobs["sparse_attn"],
                    ),
                    holdout,
                )
                results[name] = ppl
                continue
            ppl = perplexity(lambda t, cp=cp, cfg=cfg: compressed_forward(cp, cfg, t), holdout)
        else:
            ppl = perplexity(lambda t: dense_forward(params, base_cfg, t), holdout)
        results[name] = ppl
    return results


def _mixed_forward(params, cp, cfg, tokens, *, use_quant, use_prune, use_sattn):
    """Forward with an arbitrary subset of techniques enabled, built on
    dense math: pruning applied by decompressing the N:M weights; sparse
    attention applied via the block mask; quantization via the packed
    tensors (when enabled, the caller uses compressed_forward instead).
    """
    import dataclasses as dc

    from .kernels.ref import nm_decompress
    from .kernels import quantize_int4  # noqa: F401

    p2 = dict(params)
    if use_prune:
        for k in list(params.keys()):
            suffix = k.split(".")[-1]
            if suffix in ("wq", "wk", "wv", "wo"):
                vals = cp[k + ".vals"]
                idx = cp[k + ".idx"]
                p2[k] = np.asarray(
                    nm_decompress(jnp.asarray(vals), jnp.asarray(idx), cfg.nm_m, params[k].shape[1])
                )
    eval_cfg = cfg if use_sattn else dc.replace(cfg, attn_window=10_000)
    return _dense_with_mask(p2, eval_cfg, tokens, use_sattn)


def _dense_with_mask(params, cfg, tokens, use_sattn):
    from .model import make_block_mask, rope_angles, apply_rope
    from .kernels.ref import block_mask_to_dense, rmsnorm_ref, silu_ref

    L = tokens.shape[0]
    x = params["embed"][tokens]
    pos = jnp.arange(L)
    cos, sin = rope_angles(cfg, pos)
    mask = jnp.tril(jnp.ones((L, L), bool))
    if use_sattn:
        bm = jnp.asarray(make_block_mask(cfg, L))
        mask = mask & block_mask_to_dense(bm, cfg.attn_block)
    for i in range(cfg.n_layers):
        h = rmsnorm_ref(x, params[f"l{i}.norm_attn"], cfg.norm_eps)
        q = (h @ params[f"l{i}.wq"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        k = (h @ params[f"l{i}.wk"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        v = (h @ params[f"l{i}.wv"].T).reshape(L, cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(cfg.head_dim)
        scores = jnp.where(mask[None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("hqk,khd->qhd", att, v).reshape(L, cfg.dim)
        x = x + o @ params[f"l{i}.wo"].T
        h = rmsnorm_ref(x, params[f"l{i}.norm_ffn"], cfg.norm_eps)
        gate = silu_ref(h @ params[f"l{i}.w1"].T)
        up = h @ params[f"l{i}.w3"].T
        x = x + (gate * up) @ params[f"l{i}.w2"].T
    x = rmsnorm_ref(x, params["norm_f"], cfg.norm_eps)
    return x @ params["head"].T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=Path("../artifacts/table4.json"))
    ap.add_argument("--params", type=Path, default=PARAMS_FILE)
    args = ap.parse_args()
    if args.params.exists():
        with np.load(args.params) as z:
            params = {k: z[k] for k in z.files}
        print(f"using trained params {args.params}")
    else:
        print("WARNING: random params (run compile.train)")
        params = init_params(np.random.default_rng(0), TINY)
    corpus = make_corpus(vocab=TINY.vocab, n_tokens=200_000, seed=0)
    _, holdout = split_corpus(corpus)
    results = evaluate(params, TINY, holdout)
    print(f"{'Compression':<18} {'ppl (held-out)':>14}")
    for name, ppl in results.items():
        print(f"{name:<18} {ppl:>14.2f}")
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(results, indent=1))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
