"""Build-time training of the tiny model on the synthetic corpus.

This gives the E2E serving demo a model whose generations are actually
predictable (low-entropy Markov text) and makes Table 4's perplexity
comparison meaningful.  Hand-rolled Adam — no optax in this image.

Run: python -m compile.train [--steps N] [--out params_tiny.npz]
"""

from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import batches, make_corpus, split_corpus
from .model import TINY, ModelConfig, dense_forward, init_params

DEFAULT_OUT = Path(__file__).parent / "params_tiny.npz"


def loss_fn(params, cfg: ModelConfig, batch: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy over a (B, L+1) batch."""
    inp = batch[:, :-1]
    tgt = batch[:, 1:]
    logits = jax.vmap(lambda t: dense_forward(params, cfg, t))(inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def adam_step(params, m, v, t, cfg: ModelConfig, batch, lr=3e-3):
    """One Adam update (b1=0.9, b2=0.99, eps=1e-8)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
    b1, b2, eps = 0.9, 0.99, 1e-8
    t = t + 1
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    scale = jnp.sqrt(1 - b2**t) / (1 - b1**t)
    params = jax.tree.map(
        lambda p, mi, vi: p - lr * scale * mi / (jnp.sqrt(vi) + eps),
        params, m, v,
    )
    return params, m, v, t, loss


def train(cfg: ModelConfig = TINY, steps: int = 400, seq_len: int = 128,
          batch: int = 16, seed: int = 0, log_every: int = 50,
          corpus_tokens: int = 200_000):
    rng = np.random.default_rng(seed)
    corpus = make_corpus(vocab=cfg.vocab, n_tokens=corpus_tokens, seed=seed)
    train_toks, _ = split_corpus(corpus)
    params = {k: jnp.asarray(w) for k, w in init_params(rng, cfg).items()}
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    t = jnp.int32(0)
    it = batches(train_toks, seq_len, batch, rng)
    history = []
    t0 = time.time()
    for step in range(steps):
        params, m, v, t, loss = adam_step(params, m, v, t, cfg, jnp.asarray(next(it)))
        if step % log_every == 0 or step == steps - 1:
            lv = float(loss)
            history.append((step, lv))
            print(f"step {step:5d}  loss {lv:.4f}  ppl {np.exp(lv):8.2f}  "
                  f"({time.time()-t0:.1f}s)", flush=True)
    return {k: np.asarray(w) for k, w in params.items()}, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, history = train(steps=args.steps, seed=args.seed)
    np.savez(args.out, **params)
    print(f"saved {len(params)} tensors to {args.out}")


if __name__ == "__main__":
    main()
