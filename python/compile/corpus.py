"""Synthetic tiny-corpus generator (RedPajama/WikiText stand-in).

The paper finetunes on a RedPajama subset and reports perplexity on
WikiText-2/103 (Table 4).  We have neither here, so we synthesize a corpus
with real learnable structure: a second-order Markov chain over the model
vocabulary with low-entropy transitions plus an injected "phrase book" of
recurring n-grams.  A trained model reaches low perplexity on held-out
text, and compression (sparsification / quantization) degrades it by a
small, measurable amount — the same structure Table 4 demonstrates.
"""

from __future__ import annotations

import numpy as np


def make_corpus(
    vocab: int = 512,
    n_tokens: int = 200_000,
    seed: int = 0,
    branching: int = 8,
    n_phrases: int = 64,
    phrase_len: int = 12,
    phrase_prob: float = 0.15,
) -> np.ndarray:
    """Generate a token stream with 2nd-order Markov structure.

    branching: out-degree of each (prev, cur) context — lower = lower
    entropy = lower achievable perplexity.
    """
    rng = np.random.default_rng(seed)
    # Sparse 2nd-order transition table: context -> `branching` successors.
    n_ctx = vocab  # hash (prev, cur) into vocab buckets to bound memory
    successors = rng.integers(0, vocab, size=(n_ctx, branching))
    probs = rng.dirichlet(np.ones(branching) * 0.5, size=n_ctx)
    phrases = rng.integers(0, vocab, size=(n_phrases, phrase_len))

    out = np.empty(n_tokens, dtype=np.int32)
    prev, cur = 0, 1
    i = 0
    while i < n_tokens:
        if rng.random() < phrase_prob:
            ph = phrases[rng.integers(0, n_phrases)]
            take = min(phrase_len, n_tokens - i)
            out[i : i + take] = ph[:take]
            i += take
            if i >= n_tokens:
                break
            prev, cur = int(out[i - 2]), int(out[i - 1])
            continue
        ctx = (prev * 31 + cur) % n_ctx
        nxt = int(rng.choice(successors[ctx], p=probs[ctx]))
        out[i] = nxt
        prev, cur = cur, nxt
        i += 1
    return out


def batches(tokens: np.ndarray, seq_len: int, batch: int, rng: np.random.Generator):
    """Infinite iterator of (batch, seq_len+1) windows for LM training."""
    n = len(tokens) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([tokens[s : s + seq_len + 1] for s in starts])


def split_corpus(tokens: np.ndarray, holdout: float = 0.1):
    cut = int(len(tokens) * (1 - holdout))
    return tokens[:cut], tokens[cut:]
