"""AOT lowering: JAX model -> HLO text artifacts + weights + manifest.

This is the build-time half of the three-layer stack.  It runs ONCE
(`make artifacts`); the rust coordinator then loads:

    artifacts/
      manifest.json        — param order/shape/dtype/offsets, artifact
                             signatures, model config, golden digests
      weights.bin          — compressed params, concatenated little-endian
      decode.hlo.txt       — fused always-on-chip decode step
      prefill_<L>.hlo.txt  — one module per length-adaptive prefill bucket
      goldens.bin          — golden inputs/outputs for rust integration
                             tests (decode + smallest prefill bucket)

HLO *text* is the interchange format, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    TINY,
    ModelConfig,
    compress_params,
    decode_step,
    init_params,
    param_order,
    prefill,
)

# Length-adaptive prefill buckets (§5.2): prompt lengths 1..L share the
# bucket-L executable.  Coarse on purpose — the decode stage gets the finer
# treatment because it dominates execution frequency.
PREFILL_BUCKETS = (16, 32, 64, 128)

DTYPE_TAG = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
             np.dtype(np.uint8): "u8"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is ESSENTIAL: the default printer elides
    big constants as `constant({...})`, which xla_extension 0.5.1's text
    parser silently zero-fills — every baked constant (rope tables,
    attention masks) would read as zeros on the rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def load_or_init_params(cfg: ModelConfig, params_file: Path | None):
    if params_file and params_file.exists():
        print(f"loading trained params from {params_file}")
        with np.load(params_file) as z:
            return {k: z[k] for k in z.files}
    print("WARNING: no trained params found; using random init "
          "(run `python -m compile.train` for a meaningful model)")
    return init_params(np.random.default_rng(0), cfg)


def spec_of(a: np.ndarray) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(a.shape, a.dtype)


def kv_shape(cfg: ModelConfig):
    return (cfg.n_layers, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim)


def build_artifacts(out_dir: Path, cfg: ModelConfig, params_file: Path | None,
                    buckets=PREFILL_BUCKETS) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    dense = load_or_init_params(cfg, params_file)
    cp = compress_params(dense, cfg)
    names = param_order(cfg)
    assert set(names) == set(cp.keys()), (
        sorted(set(names) ^ set(cp.keys())) or "ok")

    # ---- weights.bin + param table -------------------------------------
    manifest: dict = {"config": {
        "vocab": cfg.vocab, "dim": cfg.dim, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "ffn_dim": cfg.ffn_dim,
        "max_seq": cfg.max_seq, "nm_m": cfg.nm_m, "nm_n": cfg.nm_n,
        "quant_group": cfg.quant_group, "attn_block": cfg.attn_block,
        "attn_window": cfg.attn_window, "attn_global": cfg.attn_global,
    }, "params": [], "artifacts": {}, "prefill_buckets": list(buckets)}

    blobs = []
    offset = 0
    for name in names:
        a = np.ascontiguousarray(cp[name])
        tag = DTYPE_TAG[a.dtype]
        nbytes = a.nbytes
        manifest["params"].append({
            "name": name, "dtype": tag, "shape": list(a.shape),
            "offset": offset, "nbytes": nbytes,
        })
        blobs.append(a.tobytes())
        offset += nbytes
    weights = b"".join(blobs)
    (out_dir / "weights.bin").write_bytes(weights)
    manifest["weights_sha256"] = hashlib.sha256(weights).hexdigest()

    param_args = [jnp.asarray(cp[n]) for n in names]
    param_specs = [spec_of(np.asarray(cp[n])) for n in names]
    n_params = len(names)

    # ---- decode module ---------------------------------------------------
    def decode_flat(*args):
        d = dict(zip(names, args[:n_params]))
        token, kv, pos = args[n_params:]
        return decode_step(d, cfg, token, kv, pos)

    tok_spec = jax.ShapeDtypeStruct((1,), np.int32)
    kv_spec = jax.ShapeDtypeStruct(kv_shape(cfg), np.float32)
    pos_spec = jax.ShapeDtypeStruct((), np.int32)
    print("lowering decode ...", flush=True)
    lowered = jax.jit(decode_flat).lower(*param_specs, tok_spec, kv_spec, pos_spec)
    (out_dir / "decode.hlo.txt").write_text(to_hlo_text(lowered))
    manifest["artifacts"]["decode"] = {
        "file": "decode.hlo.txt",
        "inputs": ["params...", "token:i32[1]",
                   f"kv:f32{list(kv_shape(cfg))}", "pos:i32[]"],
        "outputs": [f"logits:f32[1,{cfg.vocab}]",
                    f"kv:f32{list(kv_shape(cfg))}"],
    }

    # ---- prefill modules (one per bucket) --------------------------------
    for L in buckets:
        def prefill_flat(*args, L=L):
            d = dict(zip(names, args[:n_params]))
            (tokens,) = args[n_params:]
            return prefill(d, cfg, tokens)

        tspec = jax.ShapeDtypeStruct((L,), np.int32)
        print(f"lowering prefill_{L} ...", flush=True)
        lowered = jax.jit(prefill_flat).lower(*param_specs, tspec)
        (out_dir / f"prefill_{L}.hlo.txt").write_text(to_hlo_text(lowered))
        manifest["artifacts"][f"prefill_{L}"] = {
            "file": f"prefill_{L}.hlo.txt",
            "inputs": ["params...", f"tokens:i32[{L}]"],
            "outputs": [f"logits:f32[1,{cfg.vocab}]",
                        f"kv:f32{list(kv_shape(cfg))}"],
        }

    # ---- goldens for rust integration tests ------------------------------
    rng = np.random.default_rng(1234)
    g_tokens = rng.integers(0, cfg.vocab, size=buckets[0], dtype=np.int32)
    g_logits_p, g_kv_p = jax.jit(
        lambda *a: prefill(dict(zip(names, a[:n_params])), cfg, a[n_params])
    )(*param_args, jnp.asarray(g_tokens))
    g_tok = np.asarray([int(np.argmax(np.asarray(g_logits_p)[0]))], np.int32)
    g_pos = np.int32(buckets[0])
    g_logits_d, g_kv_d = jax.jit(
        lambda *a: decode_step(dict(zip(names, a[:n_params])), cfg,
                               a[n_params], a[n_params + 1], a[n_params + 2])
    )(*param_args, jnp.asarray(g_tok), g_kv_p, g_pos)

    gold = {
        "prefill_tokens": g_tokens,
        "prefill_logits": np.asarray(g_logits_p),
        "prefill_kv": np.asarray(g_kv_p),
        "decode_token": g_tok,
        "decode_pos": np.asarray(g_pos),
        "decode_logits": np.asarray(g_logits_d),
        "decode_kv": np.asarray(g_kv_d),
    }
    gblobs, goffset, gentries = [], 0, []
    for gname, arr in gold.items():
        a = np.ascontiguousarray(arr)
        gentries.append({"name": gname, "dtype": DTYPE_TAG[a.dtype],
                         "shape": list(a.shape), "offset": goffset,
                         "nbytes": a.nbytes})
        gblobs.append(a.tobytes())
        goffset += a.nbytes
    (out_dir / "goldens.bin").write_bytes(b"".join(gblobs))
    manifest["goldens"] = gentries
    manifest["golden_prefill_bucket"] = buckets[0]

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    total = sum(p["nbytes"] for p in manifest["params"])
    print(f"artifacts written to {out_dir} "
          f"({len(manifest['artifacts'])} modules, weights {total/1e6:.2f} MB)")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=Path("../artifacts"))
    ap.add_argument("--params", type=Path,
                    default=Path(__file__).parent / "params_tiny.npz")
    args = ap.parse_args()
    build_artifacts(args.out, TINY, args.params)


if __name__ == "__main__":
    main()
