"""L2 model tests: decode/prefill consistency, compression sanity."""

import numpy as np
import pytest
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile.model import (
    ModelConfig,
    compress_params,
    compressed_forward,
    decode_step,
    dense_forward,
    init_params,
    param_order,
    prefill,
)

CFG = ModelConfig(
    vocab=64, dim=64, n_layers=2, n_heads=4, ffn_dim=128, max_seq=64,
    nm_m=16, nm_n=8, quant_group=32, attn_block=16, attn_window=4,
)


@pytest.fixture(scope="module")
def params():
    return init_params(np.random.default_rng(0), CFG)


@pytest.fixture(scope="module")
def cp(params):
    return compress_params(params, CFG)


class TestParamContract:
    def test_param_order_covers_compressed_exactly(self, cp):
        assert set(param_order(CFG)) == set(cp.keys())

    def test_compressed_attention_is_nm(self, cp):
        vals = cp["l0.wq.vals"]
        g = CFG.dim // CFG.nm_m
        assert vals.shape == (CFG.dim, g, CFG.nm_n)
        idx = cp["l0.wq.idx"]
        assert idx.dtype == np.int32
        assert idx.min() >= 0 and idx.max() < CFG.nm_m
        # Canonical: ascending unique indices per group.
        assert (np.diff(idx, axis=-1) > 0).all()

    def test_compressed_ffn_is_packed_int4(self, cp):
        packed = cp["l0.w1.packed"]
        assert packed.dtype == np.uint8
        assert packed.shape == (CFG.ffn_dim, CFG.dim // 2)
        scales = cp["l0.w1.scales"]
        assert scales.shape == (CFG.ffn_dim, CFG.dim // CFG.quant_group)
        assert (scales > 0).all()


class TestForwardConsistency:
    def test_prefill_then_decode_matches_full_forward(self, cp):
        """prefill(t[:L]) + decode(t[L]) must equal the compressed full
        forward over t[:L+1] — the KV cache is exact."""
        rng = np.random.default_rng(1)
        L = 16
        toks = rng.integers(0, CFG.vocab, size=L + 1).astype(np.int32)
        logits_p, kv = prefill(cp, CFG, jnp.asarray(toks[:L]))
        logits_d, _ = decode_step(
            cp, CFG, jnp.asarray(toks[L:L + 1]), kv, jnp.int32(L)
        )
        full = compressed_forward(cp, CFG, jnp.asarray(toks))
        # Note: compressed_forward uses the block mask for all L+1 rows;
        # decode attends densely to cache. With a full window (window=4,
        # 16-token blocks over 17 tokens) both see every position.
        assert_allclose(
            np.asarray(logits_d)[0], np.asarray(full)[L], rtol=2e-3, atol=2e-3
        )

    def test_decode_steps_are_incremental(self, cp):
        """Two successive decode steps must match prefill over the longer
        prompt (cache append is position-exact)."""
        rng = np.random.default_rng(2)
        toks = rng.integers(0, CFG.vocab, size=18).astype(np.int32)
        _, kv16 = prefill(cp, CFG, jnp.asarray(toks[:16]))
        l17, kv17 = decode_step(cp, CFG, jnp.asarray(toks[16:17]), kv16, jnp.int32(16))
        l18, _ = decode_step(cp, CFG, jnp.asarray(toks[17:18]), kv17, jnp.int32(17))
        full = compressed_forward(cp, CFG, jnp.asarray(toks))
        assert_allclose(np.asarray(l18)[0], np.asarray(full)[17], rtol=2e-3, atol=2e-3)

    def test_prefill_kv_padded_to_max_seq(self, cp):
        toks = np.zeros(16, np.int32)
        _, kv = prefill(cp, CFG, jnp.asarray(toks))
        assert kv.shape == (CFG.n_layers, 2, CFG.max_seq, CFG.n_heads, CFG.dim // CFG.n_heads)
        # Positions beyond the prompt are zero.
        assert np.asarray(kv)[:, :, 16:].max() == 0.0


class TestCompressionQuality:
    def test_compressed_close_to_dense_on_logits(self, params, cp):
        """Compression is lossy but bounded: top-1 agreement on most
        positions of a random sequence."""
        rng = np.random.default_rng(3)
        toks = rng.integers(0, CFG.vocab, size=32).astype(np.int32)
        dense = np.asarray(dense_forward(params, CFG, jnp.asarray(toks)))
        comp = np.asarray(compressed_forward(cp, CFG, jnp.asarray(toks)))
        agree = (dense.argmax(-1) == comp.argmax(-1)).mean()
        assert agree > 0.5, f"top-1 agreement {agree}"

    def test_all_outputs_finite(self, cp):
        toks = np.arange(32, dtype=np.int32) % CFG.vocab
        out = np.asarray(compressed_forward(cp, CFG, jnp.asarray(toks)))
        assert np.isfinite(out).all()
