"""Kernel-vs-oracle tests for block-sparse flash attention."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import block_attn, make_sliding_block_mask
from compile.kernels.ref import block_attn_ref


def rand_qkv(rng, n, d):
    q = rng.standard_normal((n, d)).astype(np.float32)
    k = rng.standard_normal((n, d)).astype(np.float32)
    v = rng.standard_normal((n, d)).astype(np.float32)
    return q, k, v


class TestBlockAttnVsRef:
    @pytest.mark.parametrize("n,block", [(64, 16), (128, 32), (128, 64)])
    def test_dense_mask_causal(self, n, block):
        """All-ones block mask == plain causal attention."""
        rng = np.random.default_rng(0)
        q, k, v = rand_qkv(rng, n, 32)
        nb = n // block
        mask = np.ones((nb, nb), dtype=bool)
        got = np.asarray(block_attn(q, k, v, mask, block=block))
        want = np.asarray(block_attn_ref(q, k, v, mask, block))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_sliding_window_mask(self):
        rng = np.random.default_rng(1)
        n, block = 256, 64
        q, k, v = rand_qkv(rng, n, 32)
        mask = make_sliding_block_mask(n // block, window=2, global_blocks=1)
        got = np.asarray(block_attn(q, k, v, mask, block=block))
        want = np.asarray(block_attn_ref(q, k, v, mask, block))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_non_causal(self):
        rng = np.random.default_rng(2)
        n, block = 128, 32
        q, k, v = rand_qkv(rng, n, 16)
        mask = np.ones((4, 4), dtype=bool)
        got = np.asarray(block_attn(q, k, v, mask, block=block, causal=False))
        want = np.asarray(block_attn_ref(q, k, v, mask, block, causal=False))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_fully_masked_rows_are_zero(self):
        """A query block whose mask row is all False outputs zeros."""
        rng = np.random.default_rng(3)
        n, block = 128, 32
        q, k, v = rand_qkv(rng, n, 16)
        mask = np.ones((4, 4), dtype=bool)
        mask[2, :] = False  # third query block sees nothing
        got = np.asarray(block_attn(q, k, v, mask, block=block))
        assert_allclose(got[2 * block : 3 * block], 0.0, atol=0)
        want = np.asarray(block_attn_ref(q, k, v, mask, block))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_masked_blocks_do_not_influence_output(self):
        """Perturbing K/V inside masked blocks must not change the result —
        the SDDMM-skip guarantee."""
        rng = np.random.default_rng(4)
        n, block = 128, 64
        q, k, v = rand_qkv(rng, n, 32)
        mask = np.array([[True, False], [False, True]])
        base = np.asarray(block_attn(q, k, v, mask, block=block))
        k2, v2 = k.copy(), v.copy()
        # Block column 0 is masked for query block 1: scribble on it.
        k2[:block] += rng.standard_normal((block, 32)).astype(np.float32) * 100
        v2[:block] += 1e6
        got = np.asarray(block_attn(q, k2, v2, mask, block=block))
        # Query block 1 (rows block..2*block) must be identical.
        assert_allclose(got[block:], base[block:], rtol=1e-6, atol=1e-6)

    def test_sm_scale_override(self):
        rng = np.random.default_rng(5)
        n, block = 64, 32
        q, k, v = rand_qkv(rng, n, 16)
        mask = np.ones((2, 2), dtype=bool)
        got = np.asarray(block_attn(q, k, v, mask, block=block, sm_scale=0.5))
        want = np.asarray(block_attn_ref(q, k, v, mask, block, sm_scale=0.5))
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestMaskGenerator:
    def test_sliding_mask_is_causal_lower_triangular(self):
        m = make_sliding_block_mask(8, window=3, global_blocks=1)
        assert not np.triu(m, k=1).any()

    def test_diagonal_always_kept(self):
        m = make_sliding_block_mask(8, window=1, global_blocks=0)
        assert np.diag(m).all()

    def test_global_blocks_present(self):
        m = make_sliding_block_mask(8, window=1, global_blocks=2)
        assert m[:, 0][2:].all() and m[:, 1][2:].all()

    def test_density_decreases_with_smaller_window(self):
        d1 = make_sliding_block_mask(16, window=2).mean()
        d2 = make_sliding_block_mask(16, window=8).mean()
        assert d1 < d2


@settings(max_examples=15, deadline=None)
@given(
    nb=st.sampled_from([2, 4]),
    block=st.sampled_from([16, 32]),
    d=st.sampled_from([16, 32]),
    window=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_attn_hypothesis(nb, block, d, window, seed):
    rng = np.random.default_rng(seed)
    n = nb * block
    q, k, v = rand_qkv(rng, n, d)
    mask = make_sliding_block_mask(nb, window=window, global_blocks=1)
    got = np.asarray(block_attn(q, k, v, mask, block=block))
    want = np.asarray(block_attn_ref(q, k, v, mask, block))
    assert_allclose(got, want, rtol=1e-3, atol=1e-4)
