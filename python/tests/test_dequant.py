"""Kernel-vs-oracle tests for the mixed-precision dequant GEMV/GEMM."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import dequant_matmul, quantize_int4
from compile.kernels.ref import dequant_matmul_ref, int4_pack, int4_unpack


class TestPackUnpack:
    def test_roundtrip_all_codes(self):
        codes = np.arange(-8, 8, dtype=np.int8).reshape(1, 16)
        packed = int4_pack(codes)
        back = np.asarray(int4_unpack(packed))
        assert (back == codes).all()

    def test_pack_is_two_codes_per_byte(self):
        codes = np.zeros((4, 64), dtype=np.int8)
        assert int4_pack(codes).shape == (4, 32)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_random(self, seed):
        rng = np.random.default_rng(seed)
        codes = rng.integers(-8, 8, size=(8, 32), dtype=np.int8)
        assert (np.asarray(int4_unpack(int4_pack(codes))) == codes).all()


class TestQuantizeInt4:
    def test_quant_error_bounded_by_scale(self):
        """|w - dequant(quant(w))| <= scale/2 per element."""
        rng = np.random.default_rng(0)
        w = rng.standard_normal((16, 128)).astype(np.float32)
        packed, scales = quantize_int4(w, group=64)
        codes = np.asarray(int4_unpack(packed)).astype(np.float32)
        deq = codes.reshape(16, 2, 64) * scales[..., None]
        err = np.abs(deq.reshape(16, 128) - w)
        bound = np.repeat(scales, 64, axis=-1) / 2 + 1e-6
        assert (err <= bound).all()

    def test_zero_weight_rows(self):
        packed, scales = quantize_int4(np.zeros((2, 64), np.float32), group=64)
        assert (np.asarray(int4_unpack(packed)) == 0).all()
        assert np.isfinite(scales).all()


class TestDequantMatmulVsRef:
    @pytest.mark.parametrize("b", [1, 4])
    @pytest.mark.parametrize("group", [32, 64])
    def test_matches_ref(self, b, group):
        rng = np.random.default_rng(5)
        o, k = 128, 128
        x = rng.standard_normal((b, k)).astype(np.float32)
        codes = rng.integers(-8, 8, size=(o, k), dtype=np.int8)
        packed = int4_pack(codes)
        scales = rng.uniform(0.01, 0.2, size=(o, k // group)).astype(np.float32)
        got = np.asarray(dequant_matmul(x, packed, scales, group=group, block_o=64))
        want = np.asarray(dequant_matmul_ref(x, packed, scales, group))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_end_to_end_quantized_linear_close_to_dense(self):
        """The full quantize→kernel path approximates the fp32 linear."""
        rng = np.random.default_rng(11)
        b, o, k = 2, 128, 256
        w = rng.standard_normal((o, k)).astype(np.float32) * 0.05
        x = rng.standard_normal((b, k)).astype(np.float32)
        packed, scales = quantize_int4(w, group=64)
        got = np.asarray(dequant_matmul(x, packed, scales, group=64))
        ref = x @ w.T
        # int4 error budget: rel tolerance driven by scale/2 per element.
        assert np.abs(got - ref).max() < 0.05 * np.sqrt(k)

    def test_tiling_invariance(self):
        rng = np.random.default_rng(2)
        x = rng.standard_normal((1, 128)).astype(np.float32)
        codes = rng.integers(-8, 8, size=(256, 128), dtype=np.int8)
        packed = int4_pack(codes)
        scales = rng.uniform(0.05, 0.1, size=(256, 2)).astype(np.float32)
        a = np.asarray(dequant_matmul(x, packed, scales, group=64, block_o=64))
        b_ = np.asarray(dequant_matmul(x, packed, scales, group=64, block_o=256))
        assert_allclose(a, b_, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    kg=st.sampled_from([2, 4]),
    group=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_hypothesis(b, kg, group, seed):
    rng = np.random.default_rng(seed)
    k = kg * group
    o = 64
    x = rng.standard_normal((b, k)).astype(np.float32)
    codes = rng.integers(-8, 8, size=(o, k), dtype=np.int8)
    packed = int4_pack(codes)
    scales = rng.uniform(0.01, 0.3, size=(o, k // group)).astype(np.float32)
    got = np.asarray(dequant_matmul(x, packed, scales, group=group, block_o=64))
    want = np.asarray(dequant_matmul_ref(x, packed, scales, group))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)
