"""Kernel-vs-oracle tests for the N:M sparse matmul (CSD-chain path)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import nm_compress, nm_spmm
from compile.kernels.ref import nm_decompress, nm_spmm_ref


def rand_nm(rng, o, k, m, n):
    """Random N:M-compressed weight with canonical (sorted, unique) indices."""
    g = k // m
    vals = rng.standard_normal((o, g, n)).astype(np.float32)
    idx = np.stack(
        [
            np.sort(rng.choice(m, size=n, replace=False))
            for _ in range(o * g)
        ]
    ).reshape(o, g, n).astype(np.int32)
    return vals, idx


class TestNmCompressDecompressRoundTrip:
    def test_exact_roundtrip_when_already_nm(self):
        """compress(decompress(c)) == c for canonical compressed forms."""
        rng = np.random.default_rng(0)
        o, k, m, n = 8, 64, 16, 4
        vals, idx = rand_nm(rng, o, k, m, n)
        dense = np.asarray(nm_decompress(vals, idx, m, k))
        vals2, idx2 = nm_compress(dense, m, n)
        # Index sets must agree where values are nonzero; values must agree.
        assert_allclose(
            np.asarray(nm_decompress(vals2, idx2, m, k)), dense, rtol=0, atol=0
        )

    def test_compress_keeps_topn_magnitude(self):
        rng = np.random.default_rng(1)
        w = rng.standard_normal((4, 32)).astype(np.float32)
        vals, idx = nm_compress(w, m=16, n=2)
        wg = w.reshape(4, 2, 16)
        kept = np.abs(np.take_along_axis(wg, idx, axis=-1))
        # Every kept magnitude >= every dropped magnitude in its group.
        for o in range(4):
            for g in range(2):
                dropped = np.delete(np.abs(wg[o, g]), idx[o, g])
                if dropped.size:
                    assert kept[o, g].min() >= dropped.max() - 1e-6


class TestNmSpmmVsRef:
    @pytest.mark.parametrize("b", [1, 4])
    @pytest.mark.parametrize("m,n", [(16, 2), (16, 4), (16, 8), (8, 4)])
    def test_matches_ref(self, b, m, n):
        rng = np.random.default_rng(42)
        o, k = 128, 64
        x = rng.standard_normal((b, k)).astype(np.float32)
        vals, idx = rand_nm(rng, o, k, m, n)
        got = np.asarray(nm_spmm(x, vals, idx, m, block_o=64))
        want = np.asarray(nm_spmm_ref(x, vals, idx, m))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_dense_case_n_equals_m(self):
        """N == M degenerates to a dense matmul (the paper's dense mode)."""
        rng = np.random.default_rng(7)
        b, o, k, m = 2, 128, 32, 8
        w = rng.standard_normal((o, k)).astype(np.float32)
        vals, idx = nm_compress(w, m=m, n=m)
        x = rng.standard_normal((b, k)).astype(np.float32)
        got = np.asarray(nm_spmm(x, vals, idx, m))
        assert_allclose(got, x @ w.T, rtol=1e-5, atol=1e-5)

    def test_gemv_b1_decode_path(self):
        rng = np.random.default_rng(9)
        o, k, m, n = 256, 128, 16, 4
        x = rng.standard_normal((1, k)).astype(np.float32)
        vals, idx = rand_nm(rng, o, k, m, n)
        got = np.asarray(nm_spmm(x, vals, idx, m))
        want = np.asarray(nm_spmm_ref(x, vals, idx, m))
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_o_tiling_invariance(self):
        """Result must not depend on the output tile size."""
        rng = np.random.default_rng(3)
        o, k, m, n = 256, 64, 16, 4
        x = rng.standard_normal((2, k)).astype(np.float32)
        vals, idx = rand_nm(rng, o, k, m, n)
        a = np.asarray(nm_spmm(x, vals, idx, m, block_o=64))
        b_ = np.asarray(nm_spmm(x, vals, idx, m, block_o=256))
        assert_allclose(a, b_, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    m=st.sampled_from([8, 16]),
    n_sel=st.sampled_from([1, 2, 4, 8]),
    o_tiles=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nm_spmm_hypothesis(b, g, m, n_sel, o_tiles, seed):
    """Property sweep: kernel == oracle over shape/sparsity space."""
    n = min(n_sel, m)
    k = g * m
    o = 64 * o_tiles
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, k)).astype(np.float32)
    vals, idx = rand_nm(rng, o, k, m, n)
    got = np.asarray(nm_spmm(x, vals, idx, m, block_o=64))
    want = np.asarray(nm_spmm_ref(x, vals, idx, m))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)
