//! The complete mapping flow (Fig. 9), end to end, with the §5.2 storage
//! progression and the §5.3/Table 3 resource report.
//!
//! Run: cargo run --release --example compile_report

use flightllm::compiler::{lower, storage_report, BucketPlan, CompilerOptions, VecSink};
use flightllm::config::Target;
use flightllm::ir::{assign_addresses, passes, Graph, Stage};
use flightllm::metrics::format_table;

fn main() -> anyhow::Result<()> {
    let t = Target::u280_llama2();
    println!("mapping {} onto {}\n", t.model.name, t.platform.name);

    // ---- IR export + optimization (Fig. 9 steps 1-3) ----------------
    let mut g = Graph::from_model(&t.model, &t.compression, Stage::Decode { ctx: 512 });
    let before = g.nodes.len();
    let stats = passes::optimize(&mut g);
    println!("IR: {} nodes → {} (removed {} views, fused {} misc ops)",
        before, g.nodes.len(), stats.views_removed, stats.ops_fused);

    // ---- memory assignment (Fig. 9 step 4) ---------------------------
    let map = assign_addresses(&g, &t.platform)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("memory: {:.2} GB HBM (weights+KV), {:.1} KB DDR (tables)",
        map.hbm_used as f64 / 1e9, map.ddr_used as f64 / 1e3);

    // ---- instruction generation (Fig. 9 step 5) ----------------------
    let mut sink = VecSink::default();
    lower(&g, &t, CompilerOptions::full(), &mut sink);
    println!("decode stream @ctx=512: {} instructions ({} KiB)",
        sink.0.len(), sink.0.len() * 16 / 1024);

    // ---- length-adaptive buckets (§5.2) -------------------------------
    let plan = BucketPlan::paper_default(t.model.max_seq);
    println!("\nbuckets: {} decode + {} prefill (vs {} naive streams)",
        plan.decode.len(), plan.prefill.len(), plan.naive_streams(3));

    // ---- storage progression (the 1.67 TB → 3.25 GB table) -----------
    println!("\ncomputing storage progression (sweeps all buckets)...");
    let r = storage_report(&t);
    let rows = vec![
        vec!["naive (all lengths × SLRs, unmerged)".into(),
             format!("{:.2} GB", r.naive_bytes / 1e9), "1.0×".into()],
        vec!["+ length-adaptive buckets".into(),
             format!("{:.2} GB", r.bucketed_bytes / 1e9),
             format!("{:.0}×", r.naive_bytes / r.bucketed_bytes)],
        vec!["+ shared file across SLRs".into(),
             format!("{:.3} GB", r.shared_bytes / 1e9),
             format!("{:.0}×", r.naive_bytes / r.shared_bytes)],
        vec!["+ merged multi-channel LD/ST".into(),
             format!("{:.3} GB", r.merged_bytes / 1e9),
             format!("{:.0}×", r.total_reduction())],
    ];
    println!("{}", format_table(
        "§5.2 instruction storage (paper: 1.67 TB → 4.77 GB → 3.25 GB, ~500×)",
        &["rung", "stored", "reduction"], &rows));

    // ---- Table 3: resources -------------------------------------------
    let res = t.accel.resources();
    let u = t.accel.utilization(&t.platform);
    let rows = vec![
        vec!["DSP".into(), format!("{}", res.dsp), format!("{:.1}%", u.dsp * 100.0), "6345 (70.2%)".into()],
        vec!["BRAM".into(), format!("{}", res.bram), format!("{:.1}%", u.bram * 100.0), "1252 (62.1%)".into()],
        vec!["URAM".into(), format!("{}", res.uram), format!("{:.1}%", u.uram * 100.0), "792 (82.5%)".into()],
        vec!["LUT".into(), format!("{}k", res.lut / 1000), format!("{:.1}%", u.lut * 100.0), "574k (44.0%)".into()],
        vec!["FF".into(), format!("{}k", res.ff / 1000), format!("{:.1}%", u.ff * 100.0), "943k (36.2%)".into()],
    ];
    println!("{}", format_table(
        "Table 3: U280 utilization (analytical RTL model vs paper)",
        &["resource", "used", "util", "paper"], &rows));
    println!("compile_report OK");
    Ok(())
}
