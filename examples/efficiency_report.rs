//! Fig. 1 in one screen: performance + energy + cost efficiency of
//! FlightLLM (U280 and VHK158) against every baseline, on the paper's
//! headline point.
//!
//! Run: cargo run --release --example efficiency_report

use flightllm::baselines::{cta, dfx, fact, GpuStack, GpuSystem};
use flightllm::config::Target;
use flightllm::experiments::flightllm_full;
use flightllm::metrics::{format_table, EvalPoint, Measurement};

fn row(m: &Measurement) -> Vec<String> {
    vec![
        m.system.clone(),
        format!("{:.3}", m.latency_s),
        format!("{:.1}", m.decode_tps),
        format!("{:.0}", m.power_w),
        format!("{:.3}", m.tokens_per_joule()),
        format!("{:.2}", m.tokens_per_s_per_dollar() * 1000.0),
    ]
}

fn main() {
    let pt = EvalPoint { prefill: 128, decode: 512 };
    for target in [Target::u280_llama2(), Target::u280_opt()] {
        let model = &target.model;
        let mut rows = Vec::new();
        rows.push(row(&GpuSystem::v100s(GpuStack::Naive).model().measure(model, pt)));
        rows.push(row(&GpuSystem::v100s(GpuStack::Opt).model().measure(model, pt)));
        rows.push(row(&GpuSystem::a100(GpuStack::Naive).model().measure(model, pt)));
        rows.push(row(&GpuSystem::a100(GpuStack::Opt).model().measure(model, pt)));
        rows.push(row(&dfx().measure(model, pt)));
        rows.push(row(&cta().measure(model, pt)));
        rows.push(row(&fact().measure(model, pt)));
        rows.push(row(&flightllm_full(&target, pt)));
        let vhk = Target { model: model.clone(), ..Target::vhk158_llama2() };
        rows.push(row(&flightllm_full(&vhk, pt)));
        println!(
            "{}",
            format_table(
                &format!("{} @ {} — latency / throughput / efficiency", model.name, pt.label()),
                &["system", "latency(s)", "tok/s", "W", "tok/J", "tok/s/k$"],
                &rows
            )
        );
    }
    println!("efficiency_report OK");
}
