//! Quickstart: the three layers in one page.
//!
//! 1. Ask the simulator for a FlightLLM-on-U280 decode-step estimate on
//!    LLaMA2-7B (no artifacts needed — shapes drive everything).
//! 2. If `make artifacts` has been run, load the real tiny model through
//!    the PJRT runtime and generate a few tokens.
//!
//! Run: cargo run --release --example quickstart

use flightllm::config::Target;
use flightllm::experiments::{flightllm_full, FlightConfig};
use flightllm::metrics::EvalPoint;

fn main() -> anyhow::Result<()> {
    // ---- 1. analytical/simulated path -------------------------------
    let target = Target::u280_llama2();
    let pt = EvalPoint { prefill: 128, decode: 128 };
    let m = flightllm_full(&target, pt);
    println!("FlightLLM on {} / {}:", target.platform.name, target.model.name);
    println!("  point {}  end-to-end latency {:.3} s", pt.label(), m.latency_s);
    println!("  decode throughput {:.1} tokens/s", m.decode_tps);
    println!("  decode HBM bandwidth utilization {:.1}%", m.bw_util * 100.0);
    println!("  power {:.1} W  → {:.2} tokens/J", m.power_w, m.tokens_per_joule());
    let _ = FlightConfig::Full; // see fig14_breakdown for the ablation

    // ---- 2. real numerics through PJRT (if artifacts exist) ---------
    generate_demo()?;
    println!("quickstart OK");
    Ok(())
}

#[cfg(feature = "xla")]
fn generate_demo() -> anyhow::Result<()> {
    use flightllm::runtime::ModelRuntime;

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts/ not built — run `make artifacts` to enable");
        println!(" the real tiny-model generation demo)");
        return Ok(());
    }
    println!("\nLoading tiny model artifacts (compiling 5 HLO modules)...");
    let rt = ModelRuntime::load(dir)?;
    let prompt: Vec<i32> = vec![17, 42, 7, 100, 255, 3, 9, 12];
    let p = rt.prefill(&prompt)?;
    let mut tok = ModelRuntime::argmax(&p.logits);
    let mut kv = p.kv;
    let mut pos = rt.bucket_for(prompt.len())? as i32;
    print!("generated:");
    for _ in 0..16 {
        print!(" {tok}");
        let out = rt.decode(tok, &kv, pos)?;
        tok = ModelRuntime::argmax(&out.logits);
        kv = out.kv;
        pos += 1;
    }
    println!();
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn generate_demo() -> anyhow::Result<()> {
    println!("\n(built without the `xla` feature — rebuild with `--features xla`");
    println!(" for the real tiny-model generation demo)");
    Ok(())
}
