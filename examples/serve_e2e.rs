//! End-to-end serving demo — the E2E validation required by DESIGN.md:
//! all three layers compose on a real workload.
//!
//! Loads the trained tiny model (L2/L1 artifacts) through the PJRT
//! runtime, serves a Poisson request trace through the L3 coordinator
//! (scheduler + paged KV manager + sampler), reports real latency /
//! throughput, and prints the paper-metric estimates the simulator gives
//! for the same workload on the U280.
//!
//! Run: make artifacts && cargo run --release --example serve_e2e

use flightllm::config::Target;
use flightllm::coordinator::{Sampler, SchedulerConfig, Server};
use flightllm::experiments::flightllm_full;
use flightllm::metrics::EvalPoint;
use flightllm::runtime::ModelRuntime;
use flightllm::workload::{generate_trace, TraceConfig};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("loading runtime (compiling HLO modules)...");
    let rt = ModelRuntime::load(dir)?;
    let max_seq = rt.manifest.config.max_seq as usize;

    let trace_cfg = TraceConfig {
        rate_per_s: 4.0,
        n_requests: 12,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        vocab: rt.vocab() as u32,
        seed: 7,
    };
    let trace = generate_trace(&trace_cfg);
    println!(
        "serving {} requests (prompts {:?}, decode {:?}, batch=1)...",
        trace.len(),
        trace_cfg.prompt_len_choices,
        trace_cfg.decode_len_choices
    );

    let mut server = Server::new(
        rt,
        SchedulerConfig {
            max_batch: 1,
            kv_pages: 128,
            page_tokens: 16,
            max_seq,
        },
        Sampler::greedy(),
    );
    let stats = server.run_trace(trace)?;

    println!("\n== E2E serving results (tiny model, PJRT CPU) ==");
    println!("requests completed   {}", stats.results.len());
    println!("wall time            {:.2} s", stats.wall_s);
    println!("decode steps         {}", stats.decode_steps);
    println!("decode throughput    {:.1} tokens/s", stats.decode_tps());
    println!("mean TTFT            {:.1} ms", stats.mean_ttft_s() * 1e3);
    println!("mean request latency {:.1} ms", stats.mean_latency_s() * 1e3);
    for r in stats.results.iter().take(3) {
        println!(
            "  req {:>2}: prompt {:>3} tokens → {:?}...",
            r.id,
            r.prompt_len,
            &r.tokens[..r.tokens.len().min(8)]
        );
    }

    // What the same workload costs on the simulated U280 at 7B scale.
    let t = Target::u280_llama2();
    let m = flightllm_full(&t, EvalPoint { prefill: 64, decode: 32 });
    println!("\n== simulator estimate: same shape on U280 / LLaMA2-7B ==");
    println!("latency {:.3} s   decode {:.1} tok/s   bw util {:.1}%",
        m.latency_s, m.decode_tps, m.bw_util * 100.0);
    println!("serve_e2e OK");
    Ok(())
}
