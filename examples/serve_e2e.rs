//! End-to-end serving demo — the E2E validation required by DESIGN.md:
//! all three layers compose on a real workload.
//!
//! Sections (the PJRT one needs `--features xla` + `make artifacts`;
//! everything else runs on the deterministic virtual clock and is
//! exercised in CI):
//!
//! 1. (xla only) the trained tiny model served through the PJRT runtime
//!    — measured host latencies.
//! 2. The same trace shape on the simulated U280 at 7B scale —
//!    deterministic FlightLLM latencies.
//! 3. Prefix caching: a shared-prefix trace served cache-off then
//!    cache-on (CoW paged-KV win, identical tokens).
//! 4. The LIVE serving front-end in virtual-clock mode: requests
//!    submitted through `Service::submit` stream tokens through their
//!    `RequestHandle`s, one request is cancelled mid-prefill (its KV
//!    pages come back immediately) and one mid-decode (its partial
//!    tokens are kept) — all under manual `tick`/`drain`, so the run
//!    is replayable.
//! 5. Chunked prefill: the TTFT / P99-ITL-vs-chunk-size sweep on a
//!    mixed burst, byte-identical tokens asserted.
//! 6. Overload + swap-to-DDR (§4.4 hybrid placement): the same
//!    overload trace with an over-provisioned pool, a small pool that
//!    spills to DDR (everything completes byte-identically, spill
//!    priced on the clock) and the small pool with legacy truncation
//!    (requests lost).
//! 7. The multi-shard fleet (SLR/board replication): the same overload
//!    burst on one board and on a 2-shard fleet — byte-identical token
//!    streams, strictly better P99 TTFT, per-shard + merged summaries
//!    — plus prefix-affinity vs round-robin hit rates on a
//!    shared-prefix trace with per-shard prefix caches.
//! 8. The flight recorder: the overload trace re-served with the event
//!    ring installed — bit-identical stats, a Perfetto trace that
//!    parses back through `util::Json`, and the run's Prometheus
//!    metrics out of `ServeStats::metrics_registry`.
//!
//! Before any serving, the static verifier checks every instruction
//! stream the simulated target can execute (occupancy, addresses,
//! channel runs, sync discipline) — the same gate `flightllm verify`
//! runs in CI.
//!
//! Run: cargo run --release --example serve_e2e
//!      (add --features xla && make artifacts for section 1)

use flightllm::config::Target;
use flightllm::coordinator::{
    RoutePolicy, Sampler, SchedulerConfig, Server, Service, SimBackend, StreamEvent,
};
use flightllm::experiments::{
    flightllm_overload_three_way, flightllm_serve_chunk_sweep, flightllm_serve_overload_recorded,
    flightllm_serve_prefix, flightllm_serve_sharded, FleetSpec,
};
use flightllm::obs::perfetto_trace;
use flightllm::workload::{
    generate_overload_trace, generate_shared_prefix_trace, generate_trace, MixedBurstConfig,
    OverloadConfig, Request, SharedPrefixConfig, TraceConfig,
};

fn main() -> anyhow::Result<()> {
    let vocab = 512u32;
    let trace_cfg = TraceConfig {
        rate_per_s: 4.0,
        n_requests: 12,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        vocab,
        seed: 7,
        ..Default::default()
    };

    // -- Section 1: PJRT runtime (xla builds with artifacts only) ------
    run_pjrt_section(&trace_cfg)?;

    // -- Section 2: the trace on the simulated U280 / LLaMA2-7B --------
    let t = Target::u280_llama2();

    // Gate: statically verify every instruction stream this target can
    // execute before handing any of them to the simulator.
    let report = flightllm::verify::verify_target(&t);
    println!(
        "== static verifier: {} streams, {} instructions on {} ==",
        report.streams.len(),
        report.total_instructions(),
        report.target
    );
    if !report.is_clean() {
        for s in &report.streams {
            for d in &s.diags {
                eprintln!("  {}: {d}", s.label);
            }
        }
        anyhow::bail!("{} verifier diagnostics on {}", report.total_diags(), report.target);
    }
    println!("all streams verify clean\n");
    let sim_max_seq = t.model.max_seq as usize;
    let mut sim_server = Server::new(
        SimBackend::with_vocab(t.clone(), vocab as usize),
        SchedulerConfig {
            max_batch: 1,
            kv_pages: 512,
            page_tokens: 16,
            max_seq: sim_max_seq,
            ..Default::default()
        },
        Sampler::greedy(),
    );
    let sim_stats = sim_server.run_trace(generate_trace(&trace_cfg))?;
    println!("== trace on simulated U280 / LLaMA2-7B (virtual clock) ==");
    println!("{}", sim_stats.summary("virtual"));

    // -- Section 3: prefix caching, cache-off vs cache-on --------------
    let px_cfg = SharedPrefixConfig {
        n_requests: 12,
        vocab,
        rate_per_s: 32.0,
        ..Default::default()
    };
    let px_off = flightllm_serve_prefix(&t, &px_cfg, 4, false);
    let px_on = flightllm_serve_prefix(&t, &px_cfg, 4, true);
    println!("\n== shared-prefix trace, simulated U280, batch 4 (virtual clock) ==");
    println!("-- prefix cache OFF --\n{}", px_off.summary("virtual"));
    println!("-- prefix cache ON --\n{}", px_on.summary("virtual"));
    println!(
        "prefix caching: {:.0}% hit rate, mean TTFT {:.1} -> {:.1} ms, peak KV {} -> {} pages",
        px_on.prefix_hit_rate() * 100.0,
        px_off.mean_ttft_s() * 1e3,
        px_on.mean_ttft_s() * 1e3,
        px_off.peak_kv_pages,
        px_on.peak_kv_pages
    );

    // -- Section 4: live front-end, streaming + cancellation -----------
    println!("\n== live service (virtual clock): streaming + cancellation ==");
    let mut svc = Service::new(
        SimBackend::with_vocab(t.clone(), vocab as usize),
        SchedulerConfig {
            max_batch: 4,
            kv_pages: 512,
            page_tokens: 16,
            max_seq: sim_max_seq,
            prefill_chunk: 64,
            ..Default::default()
        },
        Sampler::greedy(),
    );
    let req = |id: u64, plen: usize, dlen: u32| Request {
        id,
        arrival_s: 0.0,
        prompt: (0..plen as u32).collect(),
        max_new_tokens: dlen,
    };
    let streamed = svc.submit(req(0, 48, 12)); // runs to completion
    let kill_prefill = svc.submit(req(1, 512, 8)); // cancelled mid-prefill
    let kill_decode = svc.submit(req(2, 32, 64)); // cancelled mid-decode

    // A few ticks in, request 1 is still chunk-prefilling its 512-token
    // prompt: cancel it and watch its pages come back immediately.
    for _ in 0..3 {
        svc.tick()?;
    }
    let pages_before = svc.scheduler().pool.used_pages();
    kill_prefill.cancel();
    svc.tick()?;
    let pages_after = svc.scheduler().pool.used_pages();
    println!(
        "cancelled req 1 mid-prefill: KV pages {pages_before} -> {pages_after} \
         (released at the next tick)"
    );
    assert!(pages_after < pages_before, "cancellation must free pages");

    // Let request 2 decode a little, then cancel it mid-generation.
    for _ in 0..6 {
        svc.tick()?;
    }
    kill_decode.cancel();
    svc.drain()?;

    // Stream request 0's tokens exactly as a live client would.
    let mut tokens = Vec::new();
    let result = loop {
        match streamed.try_event() {
            Some(StreamEvent::Token(tok)) => tokens.push(tok),
            Some(StreamEvent::Done(r)) => break r,
            Some(StreamEvent::Rejected) => anyhow::bail!("req 0 rejected"),
            None => anyhow::bail!("req 0 stream ended without Done"),
        }
    };
    println!(
        "req 0 streamed {} tokens incrementally (first: {:?}...), ttft {:.1} ms",
        tokens.len(),
        &tokens[..tokens.len().min(6)],
        result.ttft_s * 1e3
    );
    assert_eq!(tokens, result.tokens, "stream and final result agree");
    let r1 = kill_prefill.wait().expect("cancelled handles still resolve");
    let r2 = kill_decode.wait().expect("cancelled handles still resolve");
    assert!(r1.cancelled && r1.tokens.is_empty(), "killed before first token");
    assert!(r2.cancelled && !r2.tokens.is_empty(), "partial decode kept");
    println!(
        "req 1 cancelled mid-prefill (0 tokens), req 2 cancelled mid-decode \
         ({} partial tokens kept)",
        r2.tokens.len()
    );
    let live_stats = svc.stats();
    println!("{}", live_stats.summary("virtual"));
    assert_eq!(live_stats.cancelled, 2);

    // -- Section 5: chunked prefill sweep (mixed burst) -----------------
    println!("\n== chunked prefill: P99 decode ITL vs chunk size (mixed burst) ==");
    let burst = MixedBurstConfig {
        n_decode_heavy: 4,
        decode_heavy_prompt: 32,
        decode_heavy_tokens: 48,
        n_prefill_heavy: 2,
        prefill_heavy_prompt: 1024,
        prefill_heavy_tokens: 8,
        prefill_stagger_s: 1e-6,
        vocab,
        seed: 12,
    };
    let sweep = flightllm_serve_chunk_sweep(&t, &burst, 8, &[0, 128, 256]);
    let baseline = sweep[0].1.clone();
    for (chunk, stats) in &sweep {
        for a in &baseline.results {
            let b = stats.results.iter().find(|r| r.id == a.id).unwrap();
            assert_eq!(a.tokens, b.tokens, "chunking must not change tokens");
        }
        println!(
            "  chunk {:>4}: P99 ITL {:>8.2} ms, max ITL {:>8.2} ms, mean TTFT {:>8.1} ms",
            if *chunk == 0 { "off".to_string() } else { chunk.to_string() },
            stats.p99_itl_s() * 1e3,
            stats.max_itl_s() * 1e3,
            stats.mean_ttft_s() * 1e3
        );
    }
    assert!(
        sweep[1].1.p99_itl_s() < baseline.p99_itl_s(),
        "chunked prefill must cut P99 decode ITL"
    );

    // -- Section 6: overload + swap-to-DDR preemption -------------------
    println!("\n== overload: swap-to-DDR preemption vs legacy truncation ==");
    let ov = OverloadConfig {
        n_requests: 6,
        prompt_len: 32,
        decode_len_choices: vec![48, 64, 96],
        rate_per_s: 1e6, // near-simultaneous arrivals: force residency overlap
        vocab,
        seed: 5,
    };
    let (big, swapped, lossy) = flightllm_overload_three_way(&t, &ov, 3, 64, 12, None);
    println!("-- over-provisioned pool (64 pages) --\n{}", big.summary("virtual"));
    println!("-- small pool (12 pages), swap ON --\n{}", swapped.summary("virtual"));
    println!("-- small pool (12 pages), swap OFF --\n{}", lossy.summary("virtual"));
    for a in &big.results {
        let b = swapped.results.iter().find(|r| r.id == a.id).unwrap();
        assert_eq!(a.tokens, b.tokens, "swap must resume byte-identically");
    }
    assert_eq!(swapped.preempted_truncated(), 0, "swap eliminates truncation");
    assert!(swapped.preemptions > 0 && swapped.swap_time_s > 0.0);
    assert!(lossy.preempted_truncated() > 0, "legacy baseline loses requests");
    assert!(swapped.served_s > big.served_s, "spilling is priced on the clock");
    println!(
        "swap trade: truncations {} -> 0, {} preemptions, {:.1} ms spilling over DDR",
        lossy.preempted_truncated(),
        swapped.preemptions,
        swapped.swap_time_s * 1e3
    );

    // -- Section 7: multi-shard fleet -----------------------------------
    println!("\n== fleet: 1 board vs 2 shards on the overload burst ==");
    let fleet_ov = OverloadConfig {
        n_requests: 12,
        prompt_len: 32,
        decode_len_choices: vec![32, 48],
        rate_per_s: 1e6,
        vocab,
        seed: 6,
    };
    let run_fleet = |shards: usize, route: RoutePolicy| {
        let spec = FleetSpec {
            shards,
            route,
            max_batch: 2,
            kv_pages_per_shard: 64,
            prefix_cache: false,
            vocab: vocab as usize,
            lane_threads: shards,
        };
        flightllm_serve_sharded(&t, generate_overload_trace(&fleet_ov), &spec)
    };
    let (_, single, _) = run_fleet(1, RoutePolicy::LeastLoaded);
    let (per_shard, fleet, _) = run_fleet(2, RoutePolicy::LeastLoaded);
    println!("-- 1 board --\n{}", single.summary("virtual"));
    for (i, s) in per_shard.iter().enumerate() {
        println!("-- shard {i}/2 --\n{}", s.summary("virtual"));
    }
    println!("-- fleet merged (least-loaded routing) --\n{}", fleet.summary("virtual"));
    for a in &single.results {
        let b = fleet.results.iter().find(|r| r.id == a.id).unwrap();
        assert_eq!(a.tokens, b.tokens, "sharding must not change tokens");
    }
    assert!(
        fleet.p99_ttft_s() < single.p99_ttft_s(),
        "2 shards must cut P99 TTFT on the overload burst"
    );
    assert!(fleet.served_s < single.served_s, "two boards drain faster");
    println!(
        "fleet trade: P99 TTFT {:.1} -> {:.1} ms on 2 boards",
        single.p99_ttft_s() * 1e3,
        fleet.p99_ttft_s() * 1e3
    );

    let fleet_px = SharedPrefixConfig {
        n_groups: 4,
        prefix_len: 96,
        n_requests: 16,
        rate_per_s: 1e3,
        vocab,
        ..Default::default()
    };
    let run_px = |route: RoutePolicy| {
        let spec = FleetSpec {
            shards: 2,
            route,
            max_batch: 2,
            kv_pages_per_shard: 128,
            prefix_cache: true,
            vocab: vocab as usize,
            lane_threads: 2,
        };
        flightllm_serve_sharded(&t, generate_shared_prefix_trace(&fleet_px), &spec).1
    };
    let rr = run_px(RoutePolicy::RoundRobin);
    let affine = run_px(RoutePolicy::PrefixAffinity);
    assert!(
        affine.prefix_hit_rate() >= rr.prefix_hit_rate(),
        "prefix affinity must not lose to round-robin"
    );
    println!(
        "prefix affinity on 2 shards: {:.0}% hit rate vs {:.0}% under round-robin",
        affine.prefix_hit_rate() * 100.0,
        rr.prefix_hit_rate() * 100.0
    );

    // -- Section 8: the flight recorder ---------------------------------
    println!("\n== flight recorder: events, Perfetto export, metrics registry ==");
    let (rec_stats, rec_log) =
        flightllm_serve_overload_recorded(&t, &ov, 3, 12, true, None, true);
    assert_eq!(
        rec_stats.served_s.to_bits(),
        swapped.served_s.to_bits(),
        "recording must not move the virtual clock"
    );
    let log = rec_log.expect("recording was on");
    assert_eq!(log.dropped, 0, "the ring holds the whole run");
    println!(
        "recorded {} events on lane {}: {} steps, {} prefill chunks, {} preemptions, \
         {} swap-outs / {} swap-ins, {} retired",
        log.events.len(),
        log.lane,
        log.count("step"),
        log.count("prefill_chunk"),
        log.count("preempted"),
        log.count("swap_out"),
        log.count("swap_in"),
        log.count("retired"),
    );
    assert_eq!(log.count("retired"), 6, "swap completes every request");
    assert!(log.count("preempted") > 0 && log.count("swap_out") > 0);
    let trace_json = perfetto_trace(std::slice::from_ref(&log)).to_string_pretty();
    let parsed = flightllm::util::Json::parse(&trace_json).expect("trace JSON parses");
    let n_trace_events =
        parsed.get("traceEvents").and_then(flightllm::util::Json::as_arr).unwrap().len();
    println!("Perfetto trace: {n_trace_events} trace events ({} bytes JSON)", trace_json.len());
    let registry = rec_stats.metrics_registry();
    let prom = registry.prometheus_text();
    assert!(prom.contains("flightllm_requests_completed_total 6\n"));
    println!(
        "metrics registry: {} Prometheus lines, e.g. flightllm_preemptions_total {}",
        prom.lines().count(),
        registry.counter("flightllm_preemptions_total"),
    );

    println!("serve_e2e OK");
    Ok(())
}

/// Section 1 — the PJRT runtime path.  Needs the `xla` feature and the
/// trained artifacts; skipped (with a note) when either is missing so
/// the virtual-clock sections run everywhere, CI included.
#[cfg(feature = "xla")]
fn run_pjrt_section(trace_cfg: &TraceConfig) -> anyhow::Result<()> {
    use flightllm::runtime::{ModelRuntime, RuntimeBackend};

    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("== PJRT section skipped: artifacts/ missing (run `make artifacts`) ==\n");
        return Ok(());
    }
    println!("loading runtime (compiling HLO modules)...");
    let rt = ModelRuntime::load(dir)?;
    let max_seq = rt.manifest.config.max_seq as usize;
    let vocab = rt.vocab() as u32;
    let trace = generate_trace(&TraceConfig { vocab, ..trace_cfg.clone() });
    println!(
        "serving {} requests (prompts {:?}, decode {:?}, batch=1)...",
        trace.len(),
        trace_cfg.prompt_len_choices,
        trace_cfg.decode_len_choices
    );
    let mut server = Server::new(
        RuntimeBackend::new(rt),
        SchedulerConfig {
            max_batch: 1,
            kv_pages: 128,
            page_tokens: 16,
            max_seq,
            ..Default::default()
        },
        Sampler::greedy(),
    );
    let stats = server.run_trace(trace)?;
    println!("== E2E serving results (tiny model, PJRT CPU, measured clock) ==");
    println!("{}", stats.summary("measured"));
    println!("host wall time {:.2} s\n", stats.wall_s);
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn run_pjrt_section(_trace_cfg: &TraceConfig) -> anyhow::Result<()> {
    println!("== PJRT section skipped: built without the `xla` feature ==\n");
    Ok(())
}
