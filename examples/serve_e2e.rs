//! End-to-end serving demo — the E2E validation required by DESIGN.md:
//! all three layers compose on a real workload.
//!
//! Loads the trained tiny model (L2/L1 artifacts) through the PJRT
//! runtime, serves a Poisson request trace through the L3 coordinator's
//! continuous-batching engine (batched scheduler + paged KV manager +
//! sampler), reports measured latency / throughput — then serves the
//! SAME trace shape through the `SimBackend` so the deterministic
//! FlightLLM-on-U280 numbers (virtual TTFT / latency / tokens-per-s)
//! print next to the real ones.
//!
//! Run: make artifacts && cargo run --release --features xla --example serve_e2e

use flightllm::config::Target;
use flightllm::coordinator::{Sampler, SchedulerConfig, Server, SimBackend};
use flightllm::experiments::flightllm_serve_prefix;
use flightllm::runtime::{ModelRuntime, RuntimeBackend};
use flightllm::workload::{generate_trace, SharedPrefixConfig, TraceConfig};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("loading runtime (compiling HLO modules)...");
    let rt = ModelRuntime::load(dir)?;
    let max_seq = rt.manifest.config.max_seq as usize;
    let vocab = rt.vocab() as u32;

    let trace_cfg = TraceConfig {
        rate_per_s: 4.0,
        n_requests: 12,
        prompt_len_choices: vec![16, 32, 64],
        decode_len_choices: vec![16, 32],
        vocab,
        seed: 7,
    };
    let trace = generate_trace(&trace_cfg);
    println!(
        "serving {} requests (prompts {:?}, decode {:?}, batch=1)...",
        trace.len(),
        trace_cfg.prompt_len_choices,
        trace_cfg.decode_len_choices
    );

    let mut server = Server::new(
        RuntimeBackend::new(rt),
        SchedulerConfig {
            max_batch: 1,
            kv_pages: 128,
            page_tokens: 16,
            max_seq,
            ..Default::default()
        },
        Sampler::greedy(),
    );
    let stats = server.run_trace(trace.clone())?;

    println!("\n== E2E serving results (tiny model, PJRT CPU, measured clock) ==");
    println!("{}", stats.summary("measured"));
    println!("host wall time {:.2} s", stats.wall_s);
    for r in stats.results.iter().take(3) {
        println!(
            "  req {:>2}: prompt {:>3} tokens → {:?}...",
            r.id,
            r.prompt_len,
            &r.tokens[..r.tokens.len().min(8)]
        );
    }

    // The same trace served by the simulated U280 at 7B scale: identical
    // scheduling, deterministic accelerator latencies on the virtual clock.
    let t = Target::u280_llama2();
    let sim_max_seq = t.model.max_seq as usize;
    let mut sim_server = Server::new(
        SimBackend::with_vocab(t.clone(), vocab as usize),
        SchedulerConfig {
            max_batch: 1,
            kv_pages: 512,
            page_tokens: 16,
            max_seq: sim_max_seq,
            ..Default::default()
        },
        Sampler::greedy(),
    );
    let sim_stats = sim_server.run_trace(trace)?;
    println!("\n== same trace on simulated U280 / LLaMA2-7B (virtual clock) ==");
    println!("{}", sim_stats.summary("virtual"));

    // Prefix caching on a shared-prefix trace (system prompts × user
    // tails): the same trace served cache-off then cache-on, so the CoW
    // paged-KV win (TTFT + peak pages, identical tokens) prints as a
    // controlled comparison.
    let px_cfg = SharedPrefixConfig {
        n_requests: 12,
        vocab,
        rate_per_s: 32.0,
        ..Default::default()
    };
    let px_off = flightllm_serve_prefix(&t, &px_cfg, 4, false);
    let px_on = flightllm_serve_prefix(&t, &px_cfg, 4, true);
    println!("\n== shared-prefix trace, simulated U280, batch 4 (virtual clock) ==");
    println!("-- prefix cache OFF --\n{}", px_off.summary("virtual"));
    println!("-- prefix cache ON --\n{}", px_on.summary("virtual"));
    println!(
        "prefix caching: {:.0}% hit rate, mean TTFT {:.1} -> {:.1} ms, peak KV {} -> {} pages",
        px_on.prefix_hit_rate() * 100.0,
        px_off.mean_ttft_s() * 1e3,
        px_on.mean_ttft_s() * 1e3,
        px_off.peak_kv_pages,
        px_on.peak_kv_pages
    );
    println!("serve_e2e OK");
    Ok(())
}
